//! C ABI for the shared library (`crate-type = ["cdylib"]`) — the
//! paper's headline deliverable: *"a C shared library linkable by any
//! programming language."*
//!
//! The surface mirrors the reference implementation's entry points:
//! `ssu_one_off` (full matrix), `ssu_partial` (one stripe partial of
//! `N`), `ssu_merge_partials` (reassemble), plus persistence
//! (`ssu_partial_save` / `ssu_partial_load`) and accessors. The
//! hand-written header lives at `include/unifrac.h`; a complete C
//! client is at `examples/c_client/main.c`.
//!
//! ## Contract
//!
//! * Every fallible function returns an `int` status: `0` on success,
//!   otherwise the stable per-error-class code from
//!   [`Error::code`] (`99` = caught panic). [`ssu_error_name`] maps a
//!   code to a static name; [`ssu_last_error`] returns the last
//!   failure's message for the calling thread.
//! * Results come back through opaque handles (`SsuMatrix*`,
//!   `SsuPartial*`) written to an out-pointer only on success; free
//!   them with `ssu_matrix_free` / `ssu_partial_free`.
//! * Every compute/IO path runs under `catch_unwind` — panics never
//!   cross into the caller. Raw-pointer handling happens before the
//!   guard; the guarded closures are pure safe Rust.

use crate::api::{merge_partials, FpWidth, JobSpec, PartialResult, UniFracJob};
use crate::error::{Error, Result, CODE_PANIC};
use crate::matrix::{CondensedMatrix, OutputFormat};
use crate::table::{read_table_bin, read_table_tsv, FeatureTable};
use crate::tree::{parse_newick, Phylogeny};
use crate::unifrac::Metric;
use std::cell::RefCell;
use std::ffi::{CStr, CString};
use std::os::raw::{c_char, c_double, c_int, c_uint};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::ptr;

/// Opaque distance-matrix handle (condensed storage + C-string ids).
pub struct SsuMatrix {
    dm: CondensedMatrix,
    ids: Vec<CString>,
}

impl SsuMatrix {
    fn new(dm: CondensedMatrix) -> Self {
        let n = dm.n_samples();
        let ids = (0..n)
            .map(|i| {
                let id = dm.ids().get(i).cloned().unwrap_or_else(|| format!("S{i}"));
                CString::new(id.replace('\0', "_")).expect("nul bytes replaced")
            })
            .collect();
        Self { dm, ids }
    }
}

/// Opaque stripe-partial handle.
pub struct SsuPartial(PartialResult);

thread_local! {
    static LAST_ERROR: RefCell<CString> =
        RefCell::new(CString::new("ok").expect("static"));
}

fn set_last_error(msg: &str) {
    let c = CString::new(msg.replace('\0', " "))
        .unwrap_or_else(|_| CString::new("error").expect("static"));
    LAST_ERROR.with(|l| *l.borrow_mut() = c);
}

fn fail(e: Error) -> c_int {
    set_last_error(&e.to_string());
    e.code()
}

/// Run a pure-safe closure behind a panic guard; an `Err` is the
/// status code to return (panics collapse to [`CODE_PANIC`]).
fn guarded<T>(f: impl FnOnce() -> Result<T>) -> std::result::Result<T, c_int> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(e)) => Err(fail(e)),
        Err(_) => {
            set_last_error("panic caught at the FFI boundary");
            Err(CODE_PANIC)
        }
    }
}

unsafe fn cstr_arg<'a>(p: *const c_char, what: &str) -> Result<&'a str> {
    if p.is_null() {
        return Err(Error::invalid(format!("{what} must not be NULL")));
    }
    CStr::from_ptr(p)
        .to_str()
        .map_err(|_| Error::invalid(format!("{what} is not valid UTF-8")))
}

/// Convert a C string argument or bail out of the enclosing FFI
/// function with its status code. Expands in place, so the (unsafe)
/// conversion stays in the `unsafe fn` body proper.
macro_rules! try_cstr {
    ($p:expr, $what:expr) => {
        match cstr_arg($p, $what) {
            Ok(s) => s,
            Err(e) => return fail(e),
        }
    };
}

fn load_problem(table_path: &str, tree_path: &str) -> Result<(Phylogeny, FeatureTable)> {
    let table = if table_path.ends_with(".bin") {
        read_table_bin(table_path)?
    } else {
        read_table_tsv(table_path)?
    };
    let tree = parse_newick(&std::fs::read_to_string(tree_path)?)?;
    Ok((tree, table))
}

fn build_spec(metric: &str, alpha: f64, fp32: bool, threads: c_uint) -> Result<JobSpec> {
    let metric = Metric::parse(metric, alpha)
        .ok_or_else(|| Error::invalid(format!("unknown metric {metric:?}")))?;
    Ok(JobSpec {
        metric,
        precision: if fp32 { FpWidth::F32 } else { FpWidth::F64 },
        threads: threads as usize,
        ..Default::default()
    })
}

/// Compute a full UniFrac distance matrix — the reference
/// implementation's `one_off`.
///
/// `table_path` is a feature table (`.tsv` or the binary `.bin`),
/// `tree_path` a Newick file, `unifrac_method` one of `unweighted`,
/// `weighted_normalized`, `weighted_unnormalized`, `generalized`
/// (`alpha` applies to the last). `fp32 != 0` computes in single
/// precision. `threads == 0` uses all cores. On success writes a fresh
/// handle to `*out` and returns 0.
///
/// # Safety
/// `table_path`, `tree_path` and `unifrac_method` must be valid
/// NUL-terminated strings; `out` must be a valid pointer.
#[no_mangle]
pub unsafe extern "C" fn ssu_one_off(
    table_path: *const c_char,
    tree_path: *const c_char,
    unifrac_method: *const c_char,
    alpha: c_double,
    fp32: c_int,
    threads: c_uint,
    out: *mut *mut SsuMatrix,
) -> c_int {
    if out.is_null() {
        return fail(Error::invalid("out pointer must not be NULL"));
    }
    *out = ptr::null_mut();
    let table_path = try_cstr!(table_path, "table_path");
    let tree_path = try_cstr!(tree_path, "tree_path");
    let metric = try_cstr!(unifrac_method, "unifrac_method");
    match guarded(|| {
        let (tree, table) = load_problem(table_path, tree_path)?;
        let spec = build_spec(metric, alpha, fp32 != 0, threads)?;
        UniFracJob::with_spec(&tree, &table, spec).run()
    }) {
        Ok(dm) => {
            *out = Box::into_raw(Box::new(SsuMatrix::new(dm)));
            0
        }
        Err(code) => code,
    }
}

/// Compute a full UniFrac distance matrix and stream it straight to
/// `out_path` without materializing it in RAM — the out-of-core
/// `one_off` for EMP-scale workloads. `format` selects the sink
/// (`"tsv"` — streamed square TSV; `"bin"` — raw condensed binary;
/// `"mmap"` — memory-mapped condensed binary, resumable: rerunning
/// after a kill continues at the first missing stripe range).
/// `max_resident_mb > 0` bounds the resident working set by sweeping
/// the stripe space in budget-sized passes. Outputs are byte-identical
/// to `ssu_one_off` + `ssu_matrix_write_tsv` of the same job.
///
/// # Safety
/// All pointer arguments must be valid NUL-terminated strings.
#[no_mangle]
pub unsafe extern "C" fn ssu_one_off_to_path(
    table_path: *const c_char,
    tree_path: *const c_char,
    unifrac_method: *const c_char,
    alpha: c_double,
    fp32: c_int,
    threads: c_uint,
    format: *const c_char,
    max_resident_mb: c_uint,
    out_path: *const c_char,
) -> c_int {
    let table_path = try_cstr!(table_path, "table_path");
    let tree_path = try_cstr!(tree_path, "tree_path");
    let metric = try_cstr!(unifrac_method, "unifrac_method");
    let format = try_cstr!(format, "format");
    let out_path = try_cstr!(out_path, "out_path");
    match guarded(|| {
        let (tree, table) = load_problem(table_path, tree_path)?;
        let mut spec = build_spec(metric, alpha, fp32 != 0, threads)?;
        spec.output_format = OutputFormat::parse(format).ok_or_else(|| {
            Error::invalid(format!(
                "unknown output format {format:?} (expected {})",
                OutputFormat::names_list()
            ))
        })?;
        if max_resident_mb > 0 {
            spec.max_resident_mb = Some(max_resident_mb as usize);
        }
        UniFracJob::with_spec(&tree, &table, spec).run_to_path(out_path).map(|_| ())
    }) {
        Ok(()) => 0,
        Err(code) => code,
    }
}

/// Compute the EMDUniFrac differential-abundance flows for one sample
/// pair and write them to `out_path` (`as_json != 0` writes the JSON
/// document, otherwise the tab-separated flow table — the same bytes
/// the CLI's `emd-flows` subcommand emits). `sample_i` / `sample_j`
/// name the pair either by sample id or by 0-based index. The distance
/// recorded in the artifact equals the pair's `weighted_unnormalized`
/// UniFrac distance.
///
/// # Safety
/// All pointer arguments must be valid NUL-terminated strings.
#[no_mangle]
pub unsafe extern "C" fn ssu_emd_flows(
    table_path: *const c_char,
    tree_path: *const c_char,
    sample_i: *const c_char,
    sample_j: *const c_char,
    as_json: c_int,
    out_path: *const c_char,
) -> c_int {
    let table_path = try_cstr!(table_path, "table_path");
    let tree_path = try_cstr!(tree_path, "tree_path");
    let si = try_cstr!(sample_i, "sample_i");
    let sj = try_cstr!(sample_j, "sample_j");
    let out_path = try_cstr!(out_path, "out_path");
    match guarded(|| {
        let (tree, table) = load_problem(table_path, tree_path)?;
        let resolve = |tok: &str| -> Result<usize> {
            if let Some(pos) = table.sample_ids().iter().position(|id| id.as_str() == tok) {
                return Ok(pos);
            }
            tok.trim().parse::<usize>().map_err(|_| {
                Error::invalid(format!("{tok:?} is neither a sample id nor a 0-based index"))
            })
        };
        let da = crate::unifrac::emd_flows(&tree, &table, resolve(si)?, resolve(sj)?)?;
        if as_json != 0 {
            let mut s = da.to_json().dump();
            s.push('\n');
            std::fs::write(out_path, s)?;
        } else {
            use std::io::Write as _;
            let mut w = std::io::BufWriter::new(std::fs::File::create(out_path)?);
            da.write_tsv(&mut w)?;
            w.flush()?;
        }
        Ok(())
    }) {
        Ok(()) => 0,
        Err(code) => code,
    }
}

/// Compute one stripe partial: the `partial_index`-th of `n_partials`
/// equal splits of the stripe space. Partials of the same problem/spec
/// merge bit-identically to `ssu_one_off` via [`ssu_merge_partials`].
///
/// # Safety
/// String arguments must be valid NUL-terminated strings; `out` must
/// be a valid pointer.
#[no_mangle]
pub unsafe extern "C" fn ssu_partial(
    table_path: *const c_char,
    tree_path: *const c_char,
    unifrac_method: *const c_char,
    alpha: c_double,
    fp32: c_int,
    threads: c_uint,
    partial_index: c_uint,
    n_partials: c_uint,
    out: *mut *mut SsuPartial,
) -> c_int {
    if out.is_null() {
        return fail(Error::invalid("out pointer must not be NULL"));
    }
    *out = ptr::null_mut();
    let table_path = try_cstr!(table_path, "table_path");
    let tree_path = try_cstr!(tree_path, "tree_path");
    let metric = try_cstr!(unifrac_method, "unifrac_method");
    match guarded(|| {
        let (tree, table) = load_problem(table_path, tree_path)?;
        let spec = build_spec(metric, alpha, fp32 != 0, threads)?;
        UniFracJob::with_spec(&tree, &table, spec)
            .run_partial_index(partial_index as usize, n_partials as usize)
    }) {
        Ok(p) => {
            *out = Box::into_raw(Box::new(SsuPartial(p)));
            0
        }
        Err(code) => code,
    }
}

/// Merge `n_parts` partials into a full distance matrix. The partials
/// must tile the stripe space exactly and agree on problem metadata;
/// gaps, overlaps and mismatches return the `merge` status code (21)
/// with details via [`ssu_last_error`].
///
/// # Safety
/// `parts` must point to `n_parts` valid `SsuPartial*` handles; `out`
/// must be a valid pointer. The input handles are NOT consumed.
#[no_mangle]
pub unsafe extern "C" fn ssu_merge_partials(
    parts: *const *const SsuPartial,
    n_parts: usize,
    out: *mut *mut SsuMatrix,
) -> c_int {
    if out.is_null() {
        return fail(Error::invalid("out pointer must not be NULL"));
    }
    *out = ptr::null_mut();
    if parts.is_null() && n_parts > 0 {
        return fail(Error::invalid("parts must not be NULL"));
    }
    // borrow the caller's handles — no deep copy of the payloads
    let mut borrowed: Vec<&PartialResult> = Vec::with_capacity(n_parts);
    for i in 0..n_parts {
        let p = *parts.add(i);
        if p.is_null() {
            return fail(Error::invalid(format!("parts[{i}] is NULL")));
        }
        borrowed.push(&(*p).0);
    }
    match guarded(|| merge_partials(&borrowed)) {
        Ok(dm) => {
            *out = Box::into_raw(Box::new(SsuMatrix::new(dm)));
            0
        }
        Err(code) => code,
    }
}

/// Persist a partial to `path` (compact self-describing binary).
///
/// # Safety
/// `p` must be a valid `SsuPartial*`; `path` a valid NUL-terminated
/// string.
#[no_mangle]
pub unsafe extern "C" fn ssu_partial_save(p: *const SsuPartial, path: *const c_char) -> c_int {
    if p.is_null() {
        return fail(Error::invalid("partial handle must not be NULL"));
    }
    let path = match cstr_arg(path, "path") {
        Ok(s) => s,
        Err(e) => return fail(e),
    };
    let part = &(*p).0;
    match guarded(|| part.save(path)) {
        Ok(()) => 0,
        Err(code) => code,
    }
}

/// Load a partial previously written by [`ssu_partial_save`].
///
/// # Safety
/// `path` must be a valid NUL-terminated string; `out` a valid pointer.
#[no_mangle]
pub unsafe extern "C" fn ssu_partial_load(
    path: *const c_char,
    out: *mut *mut SsuPartial,
) -> c_int {
    if out.is_null() {
        return fail(Error::invalid("out pointer must not be NULL"));
    }
    *out = ptr::null_mut();
    let path = match cstr_arg(path, "path") {
        Ok(s) => s,
        Err(e) => return fail(e),
    };
    match guarded(|| PartialResult::load(path)) {
        Ok(p) => {
            *out = Box::into_raw(Box::new(SsuPartial(p)));
            0
        }
        Err(code) => code,
    }
}

/// First global stripe a partial covers (0 on NULL).
///
/// # Safety
/// `p` must be NULL or a valid `SsuPartial*`.
#[no_mangle]
pub unsafe extern "C" fn ssu_partial_stripe_start(p: *const SsuPartial) -> c_uint {
    if p.is_null() {
        return 0;
    }
    (*p).0.meta().stripe_start as c_uint
}

/// Number of stripes a partial covers (0 on NULL).
///
/// # Safety
/// `p` must be NULL or a valid `SsuPartial*`.
#[no_mangle]
pub unsafe extern "C" fn ssu_partial_stripe_count(p: *const SsuPartial) -> c_uint {
    if p.is_null() {
        return 0;
    }
    (*p).0.meta().stripe_count as c_uint
}

/// Sample count of the matrix (0 on NULL).
///
/// # Safety
/// `m` must be NULL or a valid `SsuMatrix*`.
#[no_mangle]
pub unsafe extern "C" fn ssu_matrix_n_samples(m: *const SsuMatrix) -> c_uint {
    if m.is_null() {
        return 0;
    }
    (*m).dm.n_samples() as c_uint
}

/// Distance between samples `i` and `j` (NaN on NULL handle or
/// out-of-range indices; the diagonal is 0).
///
/// # Safety
/// `m` must be NULL or a valid `SsuMatrix*`.
#[no_mangle]
pub unsafe extern "C" fn ssu_matrix_get(m: *const SsuMatrix, i: c_uint, j: c_uint) -> c_double {
    if m.is_null() {
        return f64::NAN;
    }
    let dm = &(*m).dm;
    let (i, j) = (i as usize, j as usize);
    if i >= dm.n_samples() || j >= dm.n_samples() {
        return f64::NAN;
    }
    dm.get(i, j)
}

/// Sample id `i` as a NUL-terminated string owned by the handle (valid
/// until `ssu_matrix_free`; NULL on bad index).
///
/// # Safety
/// `m` must be NULL or a valid `SsuMatrix*`.
#[no_mangle]
pub unsafe extern "C" fn ssu_matrix_sample_id(m: *const SsuMatrix, i: c_uint) -> *const c_char {
    if m.is_null() {
        return ptr::null();
    }
    match (*m).ids.get(i as usize) {
        Some(id) => id.as_ptr(),
        None => ptr::null(),
    }
}

/// Length of the condensed (upper-triangle) vector: `n * (n - 1) / 2`.
///
/// # Safety
/// `m` must be NULL or a valid `SsuMatrix*`.
#[no_mangle]
pub unsafe extern "C" fn ssu_matrix_condensed_len(m: *const SsuMatrix) -> usize {
    if m.is_null() {
        return 0;
    }
    (*m).dm.condensed().len()
}

/// Copy the condensed vector (pair order (0,1), (0,2), …) into `buf`,
/// which must hold exactly [`ssu_matrix_condensed_len`] doubles.
///
/// # Safety
/// `m` must be a valid `SsuMatrix*`; `buf` must point to `buf_len`
/// writable doubles.
#[no_mangle]
pub unsafe extern "C" fn ssu_matrix_condensed(
    m: *const SsuMatrix,
    buf: *mut c_double,
    buf_len: usize,
) -> c_int {
    if m.is_null() || buf.is_null() {
        return fail(Error::invalid("matrix and buf must not be NULL"));
    }
    let data = (*m).dm.condensed();
    if buf_len != data.len() {
        return fail(Error::invalid(format!(
            "buf_len {buf_len} != condensed length {}",
            data.len()
        )));
    }
    ptr::copy_nonoverlapping(data.as_ptr(), buf, data.len());
    0
}

/// Write the matrix as the standard square TSV (same formatter as the
/// Rust CLI's `--output`, so outputs diff cleanly).
///
/// # Safety
/// `m` must be a valid `SsuMatrix*`; `path` a valid NUL-terminated
/// string.
#[no_mangle]
pub unsafe extern "C" fn ssu_matrix_write_tsv(m: *const SsuMatrix, path: *const c_char) -> c_int {
    if m.is_null() {
        return fail(Error::invalid("matrix handle must not be NULL"));
    }
    let path = match cstr_arg(path, "path") {
        Ok(s) => s,
        Err(e) => return fail(e),
    };
    let dm = &(*m).dm;
    match guarded(|| dm.write_tsv(path)) {
        Ok(()) => 0,
        Err(code) => code,
    }
}

/// Free a matrix handle (NULL is a no-op).
///
/// # Safety
/// `m` must be NULL or a handle previously returned by this library,
/// not yet freed.
#[no_mangle]
pub unsafe extern "C" fn ssu_matrix_free(m: *mut SsuMatrix) {
    if !m.is_null() {
        drop(Box::from_raw(m));
    }
}

/// Free a partial handle (NULL is a no-op).
///
/// # Safety
/// `p` must be NULL or a handle previously returned by this library,
/// not yet freed.
#[no_mangle]
pub unsafe extern "C" fn ssu_partial_free(p: *mut SsuPartial) {
    if !p.is_null() {
        drop(Box::from_raw(p));
    }
}

/// Message of the calling thread's most recent failure (valid until the
/// next failing call on this thread).
#[no_mangle]
pub extern "C" fn ssu_last_error() -> *const c_char {
    LAST_ERROR.with(|l| l.borrow().as_ptr())
}

/// Static name for a status code (`"ok"`, `"merge"`, `"panic"`, …).
// b"...\0" literals keep the minimum toolchain below 1.77 (no c"" syntax)
#[allow(unknown_lints, clippy::manual_c_str_literals)]
#[no_mangle]
pub extern "C" fn ssu_error_name(code: c_int) -> *const c_char {
    let s: &'static [u8] = match code {
        0 => b"ok\0",
        10 => b"io\0",
        11 => b"newick\0",
        12 => b"table\0",
        13 => b"config\0",
        14 => b"manifest\0",
        15 => b"shape\0",
        16 => b"no_artifact\0",
        17 => b"xla\0",
        18 => b"invalid\0",
        19 => b"cli\0",
        20 => b"unsupported\0",
        21 => b"merge\0",
        22 => b"corrupt\0",
        23 => b"overloaded\0",
        24 => b"deadline\0",
        CODE_PANIC => b"panic\0",
        _ => b"unknown\0",
    };
    s.as_ptr() as *const c_char
}

/// Library version string.
#[allow(unknown_lints, clippy::manual_c_str_literals)]
#[no_mangle]
pub extern "C" fn ssu_version() -> *const c_char {
    b"unifrac 0.1.0\0".as_ptr() as *const c_char
}

/// Whether the GPU stripe engine can run on this host: `1` when a real
/// adapter was detected or the deterministic virtual device is forced
/// via `UNIFRAC_GPU_VDEV`, else `0`. `--engine gpu` (and the
/// corresponding API request) on a `0` host fails with the
/// `unsupported` status code (20) unless the `vdev` adapter is
/// selected explicitly.
#[no_mangle]
pub extern "C" fn ssu_gpu_available() -> c_int {
    c_int::from(crate::unifrac::gpu::available())
}

/// CPU capability diagnostics: the SIMD kernel path the auto dispatcher
/// selects plus the detected CPU features, as a static string like
/// `"kernel=avx2 detected=avx2,fma,avx512f"` (same text the CLI's
/// `version` subcommand prints). Honors `UNIFRAC_FORCE_SCALAR`, which is
/// read once per process. The pointer stays valid for the process
/// lifetime.
#[no_mangle]
pub extern "C" fn ssu_cpu_features() -> *const c_char {
    static FEATURES: std::sync::OnceLock<CString> = std::sync::OnceLock::new();
    FEATURES
        .get_or_init(|| {
            CString::new(crate::unifrac::simd::describe().replace('\0', " "))
                .unwrap_or_else(|_| CString::new("kernel=scalar").expect("static"))
        })
        .as_ptr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::SynthSpec;
    use crate::table::write_table_tsv;
    use crate::tree::write_newick;

    /// Write a small synthetic problem to disk, return the paths.
    fn problem_files(dir: &std::path::Path) -> (CString, CString) {
        std::fs::create_dir_all(dir).unwrap();
        let (tree, table) =
            SynthSpec { n_samples: 14, n_features: 96, density: 0.1, ..Default::default() }
                .generate();
        let t_path = dir.join("t.tsv");
        let n_path = dir.join("t.nwk");
        write_table_tsv(&table, &t_path).unwrap();
        std::fs::write(&n_path, write_newick(&tree)).unwrap();
        (
            CString::new(t_path.to_str().unwrap()).unwrap(),
            CString::new(n_path.to_str().unwrap()).unwrap(),
        )
    }

    fn tmpdir(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("unifrac_capi_tests").join(name)
    }

    #[test]
    fn one_off_partial_merge_roundtrip() {
        let dir = tmpdir("roundtrip");
        let (table_c, tree_c) = problem_files(&dir);
        let metric = CString::new("weighted_normalized").unwrap();
        unsafe {
            // full matrix
            let mut full: *mut SsuMatrix = ptr::null_mut();
            let rc = ssu_one_off(
                table_c.as_ptr(),
                tree_c.as_ptr(),
                metric.as_ptr(),
                1.0,
                0,
                1,
                &mut full,
            );
            assert_eq!(rc, 0, "one_off failed: {:?}", CStr::from_ptr(ssu_last_error()));
            assert!(!full.is_null());
            let n = ssu_matrix_n_samples(full);
            assert_eq!(n, 14);
            assert_eq!(ssu_matrix_get(full, 0, 0), 0.0);
            assert!(!ssu_matrix_sample_id(full, 0).is_null());
            assert!(ssu_matrix_sample_id(full, n).is_null());

            // three partials, one persisted through save/load
            let mut parts: Vec<*mut SsuPartial> = Vec::new();
            for i in 0..3u32 {
                let mut p: *mut SsuPartial = ptr::null_mut();
                let rc = ssu_partial(
                    table_c.as_ptr(),
                    tree_c.as_ptr(),
                    metric.as_ptr(),
                    1.0,
                    0,
                    1,
                    i,
                    3,
                    &mut p,
                );
                assert_eq!(rc, 0, "partial {i}: {:?}", CStr::from_ptr(ssu_last_error()));
                parts.push(p);
            }
            let save_path = CString::new(dir.join("p1.bin").to_str().unwrap()).unwrap();
            assert_eq!(ssu_partial_save(parts[1], save_path.as_ptr()), 0);
            let mut reloaded: *mut SsuPartial = ptr::null_mut();
            assert_eq!(ssu_partial_load(save_path.as_ptr(), &mut reloaded), 0);
            assert_eq!(
                ssu_partial_stripe_start(reloaded),
                ssu_partial_stripe_start(parts[1])
            );
            assert_eq!(
                ssu_partial_stripe_count(reloaded),
                ssu_partial_stripe_count(parts[1])
            );
            ssu_partial_free(parts[1]);
            parts[1] = reloaded;

            // merge and compare: exactly equal to one_off
            let const_parts: Vec<*const SsuPartial> =
                parts.iter().map(|&p| p as *const SsuPartial).collect();
            let mut merged: *mut SsuMatrix = ptr::null_mut();
            let rc = ssu_merge_partials(const_parts.as_ptr(), const_parts.len(), &mut merged);
            assert_eq!(rc, 0, "merge: {:?}", CStr::from_ptr(ssu_last_error()));
            for i in 0..n {
                for j in 0..n {
                    let a = ssu_matrix_get(full, i, j);
                    let b = ssu_matrix_get(merged, i, j);
                    assert_eq!(a, b, "({i},{j})");
                }
            }
            // condensed export
            let len = ssu_matrix_condensed_len(merged);
            assert_eq!(len, (n as usize) * (n as usize - 1) / 2);
            let mut buf = vec![0.0f64; len];
            assert_eq!(ssu_matrix_condensed(merged, buf.as_mut_ptr(), len), 0);
            assert!(buf.iter().any(|&x| x > 0.0));
            assert_ne!(ssu_matrix_condensed(merged, buf.as_mut_ptr(), len - 1), 0);

            // TSV writer works from the handle
            let tsv = CString::new(dir.join("dm.tsv").to_str().unwrap()).unwrap();
            assert_eq!(ssu_matrix_write_tsv(merged, tsv.as_ptr()), 0);

            for p in parts {
                ssu_partial_free(p);
            }
            ssu_matrix_free(full);
            ssu_matrix_free(merged);
        }
    }

    #[test]
    fn error_paths_report_codes() {
        let metric = CString::new("weighted_normalized").unwrap();
        let missing = CString::new("/nonexistent/table.tsv").unwrap();
        let tree = CString::new("/nonexistent/tree.nwk").unwrap();
        unsafe {
            let mut out: *mut SsuMatrix = ptr::null_mut();
            let rc = ssu_one_off(
                missing.as_ptr(),
                tree.as_ptr(),
                metric.as_ptr(),
                1.0,
                0,
                1,
                &mut out,
            );
            assert_ne!(rc, 0);
            assert!(out.is_null());
            let msg = CStr::from_ptr(ssu_last_error()).to_str().unwrap();
            assert!(!msg.is_empty());
            // NULL argument rejection
            let rc = ssu_one_off(
                ptr::null(),
                tree.as_ptr(),
                metric.as_ptr(),
                1.0,
                0,
                1,
                &mut out,
            );
            assert_eq!(rc, Error::invalid("").code());
            // bad metric name
            let dir = tmpdir("errs");
            let (table_c, tree_c) = problem_files(&dir);
            let bad = CString::new("nope").unwrap();
            let rc = ssu_one_off(
                table_c.as_ptr(),
                tree_c.as_ptr(),
                bad.as_ptr(),
                1.0,
                0,
                1,
                &mut out,
            );
            assert_eq!(rc, Error::invalid("").code());
            // merging nothing is a merge error
            let mut merged: *mut SsuMatrix = ptr::null_mut();
            let rc = ssu_merge_partials(ptr::null(), 0, &mut merged);
            assert_eq!(rc, 21, "empty merge must report the merge code");
        }
    }

    #[test]
    fn one_off_to_path_matches_in_memory_tsv() {
        let dir = tmpdir("to_path");
        let (table_c, tree_c) = problem_files(&dir);
        let metric = CString::new("weighted_normalized").unwrap();
        unsafe {
            // reference: in-memory handle + write_tsv
            let mut full: *mut SsuMatrix = ptr::null_mut();
            let rc = ssu_one_off(
                table_c.as_ptr(),
                tree_c.as_ptr(),
                metric.as_ptr(),
                1.0,
                0,
                1,
                &mut full,
            );
            assert_eq!(rc, 0);
            let want = dir.join("want.tsv");
            let want_c = CString::new(want.to_str().unwrap()).unwrap();
            assert_eq!(ssu_matrix_write_tsv(full, want_c.as_ptr()), 0);
            ssu_matrix_free(full);
            // streamed: mmap binary, then every other format
            for fmt in ["tsv", "bin", "mmap"] {
                let out = dir.join(format!("out.{fmt}"));
                let out_c = CString::new(out.to_str().unwrap()).unwrap();
                let fmt_c = CString::new(fmt).unwrap();
                let rc = ssu_one_off_to_path(
                    table_c.as_ptr(),
                    tree_c.as_ptr(),
                    metric.as_ptr(),
                    1.0,
                    0,
                    1,
                    fmt_c.as_ptr(),
                    0,
                    out_c.as_ptr(),
                );
                assert_eq!(rc, 0, "{fmt}: {:?}", CStr::from_ptr(ssu_last_error()));
                if fmt == "tsv" {
                    assert_eq!(
                        std::fs::read(&want).unwrap(),
                        std::fs::read(&out).unwrap(),
                        "streamed TSV must be byte-identical to the in-memory path"
                    );
                } else {
                    let dm = crate::matrix::CondensedFile::open(&out).unwrap();
                    let back = dir.join(format!("back.{fmt}.tsv"));
                    dm.write_tsv(&back).unwrap();
                    assert_eq!(std::fs::read(&want).unwrap(), std::fs::read(&back).unwrap());
                }
            }
            // bad format name reports invalid
            let fmt_c = CString::new("hdf5").unwrap();
            let out_c = CString::new(dir.join("x").to_str().unwrap()).unwrap();
            let rc = ssu_one_off_to_path(
                table_c.as_ptr(),
                tree_c.as_ptr(),
                metric.as_ptr(),
                1.0,
                0,
                1,
                fmt_c.as_ptr(),
                0,
                out_c.as_ptr(),
            );
            assert_eq!(rc, Error::invalid("").code());
        }
    }

    /// ISSUE-5 satellite: `include/unifrac.h` must stay in lockstep
    /// with the Rust side — every `SSU_*` status constant must match
    /// `Error::code`/`code_name`, every named code must be exported in
    /// the header, and every `ssu_*` symbol declared there must exist
    /// here (and vice versa).
    #[test]
    fn header_constants_and_exports_stay_in_sync() {
        let header_path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../include/unifrac.h");
        let header = std::fs::read_to_string(&header_path)
            .unwrap_or_else(|e| panic!("read {}: {e}", header_path.display()));
        // 1. parse `#define SSU_... <code>` lines
        let mut defined: std::collections::BTreeMap<String, i32> = Default::default();
        for line in header.lines() {
            let Some(rest) = line.trim().strip_prefix("#define SSU_") else {
                continue;
            };
            let mut parts = rest.split_whitespace();
            let (Some(name), Some(code)) = (parts.next(), parts.next()) else {
                continue;
            };
            defined.insert(name.to_string(), code.parse().expect("numeric SSU_ code"));
        }
        assert_eq!(defined.get("OK"), Some(&0), "SSU_OK must be 0");
        assert_eq!(defined.get("ERR_PANIC"), Some(&CODE_PANIC));
        // every SSU_ERR_* maps to the identically-named Error code
        for (name, code) in &defined {
            let Some(short) = name.strip_prefix("ERR_") else {
                continue;
            };
            if *code == CODE_PANIC {
                continue;
            }
            assert_eq!(
                Error::code_name(*code),
                short.to_lowercase(),
                "header SSU_{name}={code} disagrees with Error::code_name"
            );
        }
        // and every named Rust status code is exported by the header
        for code in 1..CODE_PANIC {
            let rust_name = Error::code_name(code);
            if rust_name == "unknown" {
                continue;
            }
            let macro_name = format!("ERR_{}", rust_name.to_uppercase());
            assert_eq!(
                defined.get(&macro_name),
                Some(&code),
                "Error code {code} ({rust_name}) missing from include/unifrac.h"
            );
        }
        // 2. exported function surface: header declarations == #[no_mangle] set
        let exports = [
            "ssu_one_off",
            "ssu_one_off_to_path",
            "ssu_emd_flows",
            "ssu_partial",
            "ssu_merge_partials",
            "ssu_partial_save",
            "ssu_partial_load",
            "ssu_partial_stripe_start",
            "ssu_partial_stripe_count",
            "ssu_matrix_n_samples",
            "ssu_matrix_get",
            "ssu_matrix_sample_id",
            "ssu_matrix_condensed_len",
            "ssu_matrix_condensed",
            "ssu_matrix_write_tsv",
            "ssu_matrix_free",
            "ssu_partial_free",
            "ssu_last_error",
            "ssu_error_name",
            "ssu_version",
            "ssu_cpu_features",
            "ssu_gpu_available",
        ];
        for name in exports {
            assert!(
                header.contains(&format!("{name}(")),
                "exported fn {name} not declared in include/unifrac.h"
            );
        }
        // no ssu_ function is declared in the header without a Rust export
        let mut declared: std::collections::BTreeSet<&str> = Default::default();
        for (pos, _) in header.match_indices("ssu_") {
            let tail = &header[pos..];
            let end = tail
                .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
                .unwrap_or(tail.len());
            if tail[end..].starts_with('(') {
                declared.insert(&tail[..end]);
            }
        }
        for name in &declared {
            assert!(
                exports.contains(name),
                "header declares {name} but the Rust C ABI does not export it"
            );
        }
        for name in exports {
            assert!(declared.contains(name), "header must declare {name} as a function");
        }
    }

    /// ISSUE-9 tentpole: `ssu_emd_flows` writes both artifact formats
    /// and its recorded distance equals the pair's
    /// weighted_unnormalized distance from the matrix path.
    #[test]
    fn emd_flows_writes_both_formats() {
        let dir = tmpdir("emd_flows");
        let (table_c, tree_c) = problem_files(&dir);
        let metric = CString::new("weighted_unnormalized").unwrap();
        unsafe {
            let mut full: *mut SsuMatrix = ptr::null_mut();
            let rc = ssu_one_off(
                table_c.as_ptr(),
                tree_c.as_ptr(),
                metric.as_ptr(),
                1.0,
                0,
                1,
                &mut full,
            );
            assert_eq!(rc, 0, "{:?}", CStr::from_ptr(ssu_last_error()));
            let want = ssu_matrix_get(full, 0, 1);
            ssu_matrix_free(full);

            let si = CString::new("0").unwrap();
            let sj = CString::new("1").unwrap();
            let tsv = CString::new(dir.join("flows.tsv").to_str().unwrap()).unwrap();
            let rc = ssu_emd_flows(
                table_c.as_ptr(),
                tree_c.as_ptr(),
                si.as_ptr(),
                sj.as_ptr(),
                0,
                tsv.as_ptr(),
            );
            assert_eq!(rc, 0, "{:?}", CStr::from_ptr(ssu_last_error()));
            let text = std::fs::read_to_string(dir.join("flows.tsv")).unwrap();
            assert!(text.starts_with("# emd-flows"), "bad header: {:?}", text.lines().next());
            let distance: f64 = text
                .lines()
                .next()
                .unwrap()
                .split("distance=")
                .nth(1)
                .unwrap()
                .parse()
                .unwrap();
            assert!((distance - want).abs() < 1e-12, "{distance} vs {want}");

            let json_c = CString::new(dir.join("flows.json").to_str().unwrap()).unwrap();
            let rc = ssu_emd_flows(
                table_c.as_ptr(),
                tree_c.as_ptr(),
                si.as_ptr(),
                sj.as_ptr(),
                1,
                json_c.as_ptr(),
            );
            assert_eq!(rc, 0, "{:?}", CStr::from_ptr(ssu_last_error()));
            let doc = crate::util::json::Json::parse(
                &std::fs::read_to_string(dir.join("flows.json")).unwrap(),
            )
            .unwrap();
            assert!((doc.get("distance").unwrap().as_f64().unwrap() - want).abs() < 1e-12);
            assert!(!doc.get("rows").unwrap().as_arr().unwrap().is_empty());

            // unknown sample token is a typed invalid error
            let bad = CString::new("no_such_sample").unwrap();
            let rc = ssu_emd_flows(
                table_c.as_ptr(),
                tree_c.as_ptr(),
                bad.as_ptr(),
                si.as_ptr(),
                0,
                tsv.as_ptr(),
            );
            assert_eq!(rc, Error::invalid("").code());
        }
    }

    #[test]
    fn error_names_match_error_codes() {
        unsafe {
            // the FFI table must agree with Error::code_name over the
            // whole code space (both say "unknown" off the mapping), so
            // a new Error variant cannot drift silently
            for code in -1..=100 {
                let got = CStr::from_ptr(ssu_error_name(code)).to_str().unwrap();
                assert_eq!(got, Error::code_name(code), "drift at code {code}");
            }
            assert_eq!(
                CStr::from_ptr(ssu_error_name(CODE_PANIC)).to_str().unwrap(),
                "panic"
            );
            let v = CStr::from_ptr(ssu_version()).to_str().unwrap();
            assert!(v.contains("unifrac"));
            let f = CStr::from_ptr(ssu_cpu_features()).to_str().unwrap();
            assert!(f.contains("kernel="), "cpu features string: {f:?}");
            assert!(f.contains("detected="), "cpu features string: {f:?}");
            // stable pointer: repeated calls return the same allocation
            assert_eq!(ssu_cpu_features(), ssu_cpu_features());
            // gpu availability is a strict boolean, stable per process
            let g = ssu_gpu_available();
            assert!(g == 0 || g == 1, "ssu_gpu_available returned {g}");
            assert_eq!(g, ssu_gpu_available());
        }
    }
}
