//! # unifrac — Striped UniFrac on a Rust + JAX + Pallas three-layer stack
//!
//! A from-scratch reproduction of *"Porting and optimizing UniFrac for
//! GPUs"* (Sfiligoi, McDonald, Knight; PEARC'20). See `DESIGN.md` for the
//! system inventory and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quickstart
//!
//! The public entry point is the [`api::UniFracJob`] facade — one
//! builder over a tree + feature table that lowers to the canonical
//! [`api::JobSpec`] and covers full runs, stripe partials and merges:
//!
//! ```no_run
//! use unifrac::{Metric, UniFracJob};
//! use unifrac::synth::SynthSpec;
//!
//! let (tree, table) = SynthSpec::emp_like(128, 42).generate();
//! let dm = UniFracJob::new(&tree, &table)
//!     .metric(Metric::Unweighted)
//!     .threads(0) // all cores
//!     .run()?;
//! println!("d(0,1) = {:.4}", dm.get(0, 1));
//!
//! // distributed: compute stripe partials anywhere, merge them later
//! let job = UniFracJob::new(&tree, &table);
//! let total = job.total_stripes()?;
//! let a = job.run_partial_range(0, total / 2)?;
//! let b = job.run_partial_range(total / 2, total - total / 2)?;
//! let merged = unifrac::merge_partials(&[a, b])?;
//! assert_eq!(merged.max_abs_diff(&job.run()?), 0.0);
//! # Ok::<(), unifrac::Error>(())
//! ```
//!
//! The same three operations — `one_off`, `partial`, `merge` — are
//! exported as a C shared library (`capi`, see `include/unifrac.h`),
//! linkable from any language.
//!
//! Architecture (Python never on the compute path):
//! - **Layer 1** (`python/compile/kernels/`): Pallas stripe-update kernel,
//!   AOT-lowered at build time.
//! - **Layer 2** (`python/compile/model.py`): JAX stripe-batch graph →
//!   HLO text artifacts (`artifacts/`).
//! - **Layer 3** (this crate): phylogeny/table substrates, the striped
//!   compute engines, the unified streaming execution core (`exec`:
//!   batch pool + stripe scheduler + workers), the chip
//!   partitioner/coordinator, the PJRT runtime that executes the AOT
//!   artifacts, statistics, the `api` facade, the C ABI (`capi`) and
//!   the CLI. See `ARCHITECTURE.md` for the layer diagram.
//!
//! EMP-scale matrices (too big for RAM) stream to disk instead: see
//! [`UniFracJob::run_to_path`], the `matrix::sink` module and the
//! operator guide in `docs/emp-scale.md`.

// ISSUE 5 rustdoc gate: every public item in the documented modules
// below must carry docs (`cargo doc --no-deps` runs under
// `RUSTDOCFLAGS="-D warnings"` in CI). Modules that predate the gate
// opt out explicitly right here — shrink this ledger, don't grow it.
#![warn(missing_docs)]

pub mod error;
pub mod matrix;
#[allow(missing_docs)]
pub mod synth;
#[allow(missing_docs)]
pub mod table;
#[allow(missing_docs)]
pub mod tree;
#[allow(missing_docs)]
pub mod util;

pub use error::{Error, Result};

pub mod api;
pub mod capi;
#[allow(missing_docs)]
pub mod cli;
#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod coordinator;
#[allow(missing_docs)]
pub mod devicemodel;
pub mod distrib;
#[allow(missing_docs)]
pub mod embed;
#[allow(missing_docs)]
pub mod exec;
#[allow(missing_docs)]
pub mod report;
#[allow(missing_docs)]
pub mod runtime;
pub mod service;
pub mod stats;
pub mod unifrac;

pub use api::{
    merge_partials, Backend, FpWidth, JobSpec, PartialResult, SinkRunReport, UniFracJob,
};
pub use distrib::{supervise, FleetReport, FleetSpec};
pub use matrix::{CondensedFile, CondensedMatrix, CondensedView, OutputFormat};
pub use service::{QuerySpec, ReferenceSet, ServeConfig, Server};
pub use unifrac::Metric;
