//! # unifrac — Striped UniFrac on a Rust + JAX + Pallas three-layer stack
//!
//! A from-scratch reproduction of *"Porting and optimizing UniFrac for
//! GPUs"* (Sfiligoi, McDonald, Knight; PEARC'20). See `DESIGN.md` for the
//! system inventory and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! Architecture (Python never on the compute path):
//! - **Layer 1** (`python/compile/kernels/`): Pallas stripe-update kernel,
//!   AOT-lowered at build time.
//! - **Layer 2** (`python/compile/model.py`): JAX stripe-batch graph →
//!   HLO text artifacts (`artifacts/`).
//! - **Layer 3** (this crate): phylogeny/table substrates, the striped
//!   compute engines, the unified streaming execution core (`exec`:
//!   batch pool + stripe scheduler + workers), the chip
//!   partitioner/coordinator, the PJRT runtime that executes the AOT
//!   artifacts, statistics, and the CLI. See `ARCHITECTURE.md` for the
//!   layer diagram.

pub mod error;
pub mod matrix;
pub mod synth;
pub mod table;
pub mod tree;
pub mod util;

pub use error::{Error, Result};

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod devicemodel;
pub mod embed;
pub mod exec;
pub mod report;
pub mod runtime;
pub mod stats;
pub mod unifrac;
