//! CI bench regression gate: compare the ratio metrics emitted by the
//! bench sweeps (`BENCH_engines.json`, `BENCH_sparse.json`,
//! `BENCH_stats.json`, `BENCH_gpu.json`) against the committed floor file
//! `BENCH_baseline.json` and fail (exit 1) when any cell regresses by
//! more than the baseline's tolerance.
//!
//! The baseline stores *ratio minimums* (engine-vs-engine and
//! SIMD-vs-scalar speedups), not absolute times — ratios of runs taken
//! on the same host in the same process are stable across machines and
//! CI hardware generations, where nanosecond floors are not. A cell
//! passes when
//!
//! ```text
//! value >= min * (1 - tolerance)
//! ```
//!
//! Usage:
//!
//! ```text
//! bench_gate --baseline ../BENCH_baseline.json \
//!            [--engines BENCH_engines.json] [--sparse BENCH_sparse.json] \
//!            [--record]
//! ```
//!
//! `--record` is the ratchet mode: instead of failing, rewrite the
//! baseline with `min = max(old min, observed)` per cell, so floors
//! only ever move up (run it on a quiet reference host, commit the
//! diff). Exit codes: 0 all cells pass, 1 regression, 2 usage/IO error.
//!
//! Baseline format (all keys of a cell except `min` select the value):
//!
//! ```json
//! {"tolerance": 0.10,
//!  "cells": [
//!    {"bench": "engine_sweep", "key": "simd_speedup_tiled_f64", "min": 1.0},
//!    {"bench": "sparse_sweep", "engine": "sparse", "dtype": "f64",
//!     "density": 0.05, "field": "speedup_vs_tiled", "min": 2.0}]}
//! ```
//!
//! A cell with `key` reads a top-level number of the bench document; a
//! cell with `engine`/`dtype` (plus optional `density`) reads `field`
//! (default `"speedup_vs_tiled"`) from the matching entry of the
//! document's `rows` array.

use std::collections::BTreeMap;
use std::process::ExitCode;
use unifrac::util::json::{obj, Json};

/// One baseline cell: a value selector plus its floor.
#[derive(Clone, Debug)]
struct Cell {
    bench: String,
    key: Option<String>,
    engine: Option<String>,
    dtype: Option<String>,
    density: Option<f64>,
    field: String,
    min: f64,
}

impl Cell {
    fn from_json(j: &Json) -> Result<Cell, String> {
        let bench = j.get("bench")?.as_str().ok_or("cell bench must be a string")?.to_string();
        let min = j.get("min")?.as_f64().ok_or("cell min must be a number")?;
        let opt_str = |key: &str| -> Option<String> {
            j.get(key).ok().and_then(|v| v.as_str()).map(str::to_string)
        };
        let cell = Cell {
            bench,
            key: opt_str("key"),
            engine: opt_str("engine"),
            dtype: opt_str("dtype"),
            density: j.get("density").ok().and_then(|v| v.as_f64()),
            field: opt_str("field").unwrap_or_else(|| "speedup_vs_tiled".to_string()),
            min,
        };
        if cell.key.is_none() && cell.engine.is_none() {
            return Err("cell needs either \"key\" or an \"engine\" row selector".into());
        }
        Ok(cell)
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![("bench", Json::from(self.bench.as_str()))];
        if let Some(k) = &self.key {
            pairs.push(("key", Json::from(k.as_str())));
        }
        if let Some(e) = &self.engine {
            pairs.push(("engine", Json::from(e.as_str())));
            pairs.push(("field", Json::from(self.field.as_str())));
        }
        if let Some(d) = &self.dtype {
            pairs.push(("dtype", Json::from(d.as_str())));
        }
        if let Some(d) = self.density {
            pairs.push(("density", Json::from(d)));
        }
        pairs.push(("min", Json::from(self.min)));
        obj(pairs)
    }

    /// Human label for the PASS/FAIL line.
    fn label(&self) -> String {
        match &self.key {
            Some(k) => format!("{}::{}", self.bench, k),
            None => {
                let mut s = format!(
                    "{}::{}[{}",
                    self.bench,
                    self.field,
                    self.engine.as_deref().unwrap_or("?")
                );
                if let Some(d) = &self.dtype {
                    s.push_str(&format!(",{d}"));
                }
                if let Some(d) = self.density {
                    s.push_str(&format!(",density={d}"));
                }
                s.push(']');
                s
            }
        }
    }

    /// Pull this cell's observed value out of its bench document.
    fn lookup(&self, doc: &Json) -> Result<f64, String> {
        if let Some(key) = &self.key {
            return doc
                .get(key)
                .map_err(|e| format!("{}: {e}", self.label()))?
                .as_f64()
                .ok_or_else(|| format!("{}: not a number", self.label()));
        }
        let rows = doc
            .get("rows")
            .map_err(|e| format!("{}: {e}", self.label()))?
            .as_arr()
            .ok_or("rows must be an array")?;
        let matches_row = |row: &Json| -> bool {
            let str_eq = |key: &str, want: &Option<String>| match want {
                None => true,
                Some(w) => row.get(key).ok().and_then(|v| v.as_str()) == Some(w.as_str()),
            };
            let density_eq = match self.density {
                None => true,
                Some(d) => row.get("table_density").ok().and_then(|v| v.as_f64()) == Some(d),
            };
            str_eq("engine", &self.engine) && str_eq("dtype", &self.dtype) && density_eq
        };
        let row = rows
            .iter()
            .find(|r| matches_row(r))
            .ok_or_else(|| format!("{}: no matching row", self.label()))?;
        row.get(&self.field)
            .map_err(|e| format!("{}: {e}", self.label()))?
            .as_f64()
            .ok_or_else(|| format!("{}: not a number", self.label()))
    }
}

/// Parsed baseline: tolerance + cells.
struct Baseline {
    tolerance: f64,
    cells: Vec<Cell>,
}

impl Baseline {
    fn parse(text: &str) -> Result<Baseline, String> {
        let doc = Json::parse(text)?;
        let tolerance = doc.get("tolerance")?.as_f64().ok_or("tolerance must be a number")?;
        if !(0.0..1.0).contains(&tolerance) {
            return Err(format!("tolerance {tolerance} out of [0, 1)"));
        }
        let cells = doc
            .get("cells")?
            .as_arr()
            .ok_or("cells must be an array")?
            .iter()
            .map(Cell::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        if cells.is_empty() {
            return Err("baseline has no cells".into());
        }
        Ok(Baseline { tolerance, cells })
    }

    fn dump(&self) -> String {
        obj(vec![
            ("tolerance", Json::from(self.tolerance)),
            ("cells", Json::Arr(self.cells.iter().map(Cell::to_json).collect())),
        ])
        .dump()
    }
}

/// One checked cell, ready to print.
struct Outcome {
    label: String,
    value: f64,
    min: f64,
    floor: f64,
    pass: bool,
}

/// Check every baseline cell against its bench document. The returned
/// outcomes are in baseline order; a missing document or cell is a hard
/// error (a gate that silently skips cells gates nothing).
fn evaluate(baseline: &Baseline, docs: &BTreeMap<String, Json>) -> Result<Vec<Outcome>, String> {
    let mut out = Vec::with_capacity(baseline.cells.len());
    for cell in &baseline.cells {
        let doc = docs
            .get(&cell.bench)
            .ok_or_else(|| format!("{}: no bench document for {:?}", cell.label(), cell.bench))?;
        let value = cell.lookup(doc)?;
        let floor = cell.min * (1.0 - baseline.tolerance);
        out.push(Outcome {
            label: cell.label(),
            value,
            min: cell.min,
            floor,
            // NaN never passes: a cell the sweep failed to measure is a
            // regression, not a skip
            pass: value >= floor,
        });
    }
    Ok(out)
}

/// Ratchet: raise each cell's floor to the observed value where the
/// observation is finite and higher. Returns how many cells moved.
fn ratchet(baseline: &mut Baseline, docs: &BTreeMap<String, Json>) -> Result<usize, String> {
    let mut raised = 0;
    for cell in &mut baseline.cells {
        let doc = docs
            .get(&cell.bench)
            .ok_or_else(|| format!("{}: no bench document for {:?}", cell.label(), cell.bench))?;
        let value = cell.lookup(doc)?;
        if value.is_finite() && value > cell.min {
            cell.min = value;
            raised += 1;
        }
    }
    Ok(raised)
}

fn usage() -> String {
    "usage: bench_gate --baseline FILE [--engines FILE] [--sparse FILE] \
     [--stats FILE] [--gpu FILE] [--record]"
        .to_string()
}

fn run(argv: &[String]) -> Result<ExitCode, String> {
    let mut baseline_path = None;
    let mut engines_path = "BENCH_engines.json".to_string();
    let mut sparse_path = "BENCH_sparse.json".to_string();
    let mut stats_path = "BENCH_stats.json".to_string();
    let mut gpu_path = "BENCH_gpu.json".to_string();
    let mut record = false;
    let mut it = argv.iter();
    while let Some(arg) = it.next() {
        let mut val = |flag: &str| {
            it.next().cloned().ok_or_else(|| format!("{flag} needs a value\n{}", usage()))
        };
        match arg.as_str() {
            "--baseline" => baseline_path = Some(val("--baseline")?),
            "--engines" => engines_path = val("--engines")?,
            "--sparse" => sparse_path = val("--sparse")?,
            "--stats" => stats_path = val("--stats")?,
            "--gpu" => gpu_path = val("--gpu")?,
            "--record" => record = true,
            other => return Err(format!("unknown flag {other:?}\n{}", usage())),
        }
    }
    let baseline_path = baseline_path.ok_or_else(usage)?;
    let baseline_text = std::fs::read_to_string(&baseline_path)
        .map_err(|e| format!("read {baseline_path}: {e}"))?;
    let mut baseline = Baseline::parse(&baseline_text)
        .map_err(|e| format!("parse {baseline_path}: {e}"))?;

    // load only the documents the baseline actually references
    let mut docs = BTreeMap::new();
    for cell in &baseline.cells {
        if docs.contains_key(&cell.bench) {
            continue;
        }
        let path = match cell.bench.as_str() {
            "engine_sweep" => &engines_path,
            "sparse_sweep" => &sparse_path,
            "stats_sweep" => &stats_path,
            "gpu_sweep" => &gpu_path,
            other => return Err(format!("no file mapping for bench {other:?}")),
        };
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
        docs.insert(cell.bench.clone(), doc);
    }

    if record {
        let raised = ratchet(&mut baseline, &docs)?;
        std::fs::write(&baseline_path, baseline.dump())
            .map_err(|e| format!("write {baseline_path}: {e}"))?;
        println!("bench_gate: recorded {baseline_path} ({raised} floor(s) raised)");
        return Ok(ExitCode::SUCCESS);
    }

    let outcomes = evaluate(&baseline, &docs)?;
    let mut failures = 0;
    for o in &outcomes {
        println!(
            "  {} {:<55} {:>8.3} (floor {:.3} = min {:.3} - {:.0}%)",
            if o.pass { "PASS" } else { "FAIL" },
            o.label,
            o.value,
            o.floor,
            o.min,
            baseline.tolerance * 100.0
        );
        failures += usize::from(!o.pass);
    }
    if failures > 0 {
        eprintln!("bench_gate: {failures} cell(s) regressed past the {baseline_path} floors");
        return Ok(ExitCode::FAILURE);
    }
    println!("bench_gate: all {} cell(s) within tolerance", outcomes.len());
    Ok(ExitCode::SUCCESS)
}

fn main() -> ExitCode {
    match run(&std::env::args().skip(1).collect::<Vec<_>>()) {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("bench_gate: {msg}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline(tolerance: f64) -> Baseline {
        Baseline::parse(&format!(
            r#"{{"tolerance": {tolerance}, "cells": [
                 {{"bench": "engine_sweep", "key": "simd_speedup_tiled_f64", "min": 2.0}},
                 {{"bench": "engine_sweep", "engine": "packed", "dtype": "f64",
                   "field": "speedup_vs_tiled", "min": 4.0}},
                 {{"bench": "sparse_sweep", "engine": "sparse", "dtype": "f64",
                   "density": 0.05, "min": 5.0}}]}}"#
        ))
        .unwrap()
    }

    fn docs(simd: f64, packed: f64, sparse: f64) -> BTreeMap<String, Json> {
        let engines = Json::parse(&format!(
            r#"{{"simd_speedup_tiled_f64": {simd},
                 "rows": [
                   {{"engine": "tiled", "dtype": "f64", "speedup_vs_tiled": 1.0}},
                   {{"engine": "packed", "dtype": "f64", "speedup_vs_tiled": {packed}}}]}}"#
        ))
        .unwrap();
        let sparse_doc = Json::parse(&format!(
            r#"{{"rows": [
                   {{"engine": "sparse", "dtype": "f64", "table_density": 0.01,
                     "speedup_vs_tiled": 99.0}},
                   {{"engine": "sparse", "dtype": "f64", "table_density": 0.05,
                     "speedup_vs_tiled": {sparse}}}]}}"#
        ))
        .unwrap();
        let mut m = BTreeMap::new();
        m.insert("engine_sweep".to_string(), engines);
        m.insert("sparse_sweep".to_string(), sparse_doc);
        m
    }

    #[test]
    fn all_cells_at_baseline_pass() {
        let out = evaluate(&baseline(0.10), &docs(2.0, 4.0, 5.0)).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|o| o.pass));
    }

    #[test]
    fn within_tolerance_passes() {
        // 5% below the floors: inside the 10% band
        let out = evaluate(&baseline(0.10), &docs(1.9, 3.8, 4.75)).unwrap();
        assert!(out.iter().all(|o| o.pass));
    }

    #[test]
    fn synthetic_regression_over_10_percent_fails() {
        // the ISSUE-6 acceptance demo: a >10% slowdown on one cell must
        // flip the gate
        let out = evaluate(&baseline(0.10), &docs(2.0, 4.0 * 0.85, 5.0)).unwrap();
        let fails: Vec<_> = out.iter().filter(|o| !o.pass).collect();
        assert_eq!(fails.len(), 1);
        assert!(fails[0].label.contains("packed"), "label: {}", fails[0].label);
        // and the exact boundary passes while epsilon below it fails
        assert!(evaluate(&baseline(0.10), &docs(2.0, 3.6, 5.0)).unwrap()[1].pass);
        assert!(!evaluate(&baseline(0.10), &docs(2.0, 3.599, 5.0)).unwrap()[1].pass);
    }

    #[test]
    fn density_selector_picks_the_right_row() {
        // density 0.05 row is the gated one; the 0.01 row says 99x and
        // must not mask a regression at 0.05
        let out = evaluate(&baseline(0.10), &docs(2.0, 4.0, 1.0)).unwrap();
        assert!(!out[2].pass);
        assert!(out[2].label.contains("density=0.05"), "label: {}", out[2].label);
    }

    #[test]
    fn nan_and_missing_cells_are_hard_failures() {
        // JSON text can't carry NaN, so inject it into the parsed doc
        let mut d = docs(2.0, 4.0, 5.0);
        if let Json::Obj(o) = d.get_mut("engine_sweep").unwrap() {
            o.insert("simd_speedup_tiled_f64".to_string(), Json::Num(f64::NAN));
        }
        let out = evaluate(&baseline(0.10), &d).unwrap();
        assert!(!out[0].pass, "NaN must not pass the gate");
        // a cell whose row vanished from the sweep is an error, not a skip
        let mut d = docs(2.0, 4.0, 5.0);
        d.insert("sparse_sweep".to_string(), Json::parse(r#"{"rows": []}"#).unwrap());
        assert!(evaluate(&baseline(0.10), &d).is_err());
        // as is a missing document
        d.remove("sparse_sweep");
        assert!(evaluate(&baseline(0.10), &d).is_err());
    }

    #[test]
    fn record_ratchets_floors_up_only() {
        let mut b = baseline(0.10);
        let raised = ratchet(&mut b, &docs(2.5, 3.0, 7.0)).unwrap();
        // simd 2.0 -> 2.5 and sparse 5.0 -> 7.0 move; packed stays at
        // its committed 4.0 even though the run was slower
        assert_eq!(raised, 2);
        assert_eq!(b.cells[0].min, 2.5);
        assert_eq!(b.cells[1].min, 4.0);
        assert_eq!(b.cells[2].min, 7.0);
        // the ratcheted baseline round-trips through its own dump
        let again = Baseline::parse(&b.dump()).unwrap();
        assert_eq!(again.cells[2].min, 7.0);
        assert_eq!(again.cells[2].density, Some(0.05));
    }

    #[test]
    fn baseline_rejects_malformed_input() {
        assert!(Baseline::parse(r#"{"tolerance": 1.5, "cells": []}"#).is_err());
        assert!(Baseline::parse(r#"{"tolerance": 0.1, "cells": []}"#).is_err());
        // a cell with neither key nor engine selector selects nothing
        assert!(Baseline::parse(
            r#"{"tolerance": 0.1, "cells": [{"bench": "engine_sweep", "min": 1.0}]}"#
        )
        .is_err());
    }
}
