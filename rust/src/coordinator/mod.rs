//! The Layer-3 coordinator: chip partitioning, the streaming pipeline,
//! and the unified run driver over CPU engines and PJRT artifacts.
//!
//! This is the paper's *system* layer: Striped UniFrac splits the stripe
//! set into independent ranges ("chips" — Table 2 runs 128 CPUs / 128
//! GPUs / 4 GPUs), each chip folds every embedding batch into its own
//! stripe accumulators, and the leader assembles the condensed matrix.
//!
//! PJRT clients are thread-bound (`Rc` internally), so simulated chips
//! are described by plain-data [`ChipSpec`]s; each worker thread
//! constructs its own backend (its own PJRT client + compiled artifact —
//! exactly one "device context" per chip, as on a real cluster).

pub mod metrics;
pub mod partition;
pub mod pipeline;

pub use metrics::RunMetrics;
pub use partition::{plan_chips, ChipPlan, ChipSpec};
pub use pipeline::{run_chips_parallel, run_chips_sequential};

// The coordinator consumed its own `RunOptions` until the `UniFracJob`
// redesign; it now runs the canonical `api::JobSpec` directly, and the
// old name survives as an alias.
pub use crate::api::{Backend, JobSpec};
pub type RunOptions = JobSpec;

use crate::error::Result;
use crate::matrix::CondensedMatrix;
use crate::runtime::XlaReal;
use crate::table::FeatureTable;
use crate::tree::Phylogeny;
use crate::unifrac::EngineKind;

/// How one chip executes stripe updates — the *lowered* per-chip
/// backend descriptor `plan_chips` derives from a [`JobSpec`] (with the
/// density-aware auto engine already resolved), analogous to the exec
/// layer's `WorkerSpec`.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Pure-rust CPU engine (the paper's CPU columns).
    Cpu { engine: EngineKind, block_k: usize },
    /// AOT artifact via PJRT (the paper's GPU code path, CPU-executed
    /// here; `engine` selects the artifact flavor, e.g. "pallas_tiled"
    /// or "jnp"). `resident` keeps accumulators device-side between
    /// batches (EXPERIMENTS.md §Perf).
    Pjrt { engine: String, resident: bool },
}

impl BackendSpec {
    pub fn cpu_tiled() -> Self {
        BackendSpec::Cpu { engine: EngineKind::Tiled, block_k: 64 }
    }

    pub fn is_pjrt(&self) -> bool {
        matches!(self, BackendSpec::Pjrt { .. })
    }
}

/// Run output: the distance matrix plus run accounting.
pub struct RunOutput {
    pub dm: CondensedMatrix,
    pub metrics: RunMetrics,
}

/// Top-level driver: resolve the backend, plan chips, execute the
/// pipeline, assemble.
pub fn run<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    opts: &JobSpec,
) -> Result<RunOutput> {
    crate::unifrac::compute::reject_stripe_range(opts)?;
    let backend = opts.resolve_backend_spec(tree, table)?;
    let plan = plan_chips::<R>(table.n_samples(), opts, &backend)?;
    let (blocks, mut metrics) = if opts.parallel {
        run_chips_parallel::<R>(tree, table, &plan, opts)?
    } else {
        run_chips_sequential::<R>(tree, table, &plan, opts)?
    };
    let t0 = std::time::Instant::now();
    let metric = opts.metric;
    let dm = CondensedMatrix::from_stripes(
        table.n_samples(),
        table.sample_ids().to_vec(),
        &blocks,
        move |num, den| metric.finalize(num, den),
    )?;
    metrics.seconds_assemble = t0.elapsed().as_secs_f64();
    Ok(RunOutput { dm, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SchedulerKind;
    use crate::synth::SynthSpec;
    use crate::unifrac::{compute_unifrac, ComputeOptions};

    fn problem() -> (Phylogeny, FeatureTable) {
        SynthSpec { n_samples: 30, n_features: 200, density: 0.05, ..Default::default() }
            .generate()
    }

    #[test]
    fn coordinator_matches_plain_compute_cpu() {
        let (tree, table) = problem();
        let reference = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { batch_capacity: 8, ..Default::default() },
        )
        .unwrap();
        for chips in [1usize, 2, 5] {
            for parallel in [false, true] {
                let opts = RunOptions {
                    chips,
                    parallel,
                    batch_capacity: 8,
                    artifacts_dir: None,
                    ..Default::default()
                };
                let out = run::<f64>(&tree, &table, &opts).unwrap();
                let diff = out.dm.max_abs_diff(&reference);
                assert!(diff < 1e-12, "chips={chips} parallel={parallel}: {diff}");
                assert_eq!(out.metrics.per_chip_seconds.len(), chips.min(out.metrics.n_stripes));
            }
        }
    }

    #[test]
    fn dynamic_scheduler_matches_static() {
        let (tree, table) = problem();
        let reference = run::<f64>(
            &tree,
            &table,
            &RunOptions { chips: 3, batch_capacity: 8, artifacts_dir: None, ..Default::default() },
        )
        .unwrap();
        let out = run::<f64>(
            &tree,
            &table,
            &RunOptions {
                chips: 3,
                batch_capacity: 8,
                scheduler: SchedulerKind::Dynamic,
                artifacts_dir: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(out.dm.max_abs_diff(&reference.dm) < 1e-10);
        assert_eq!(out.metrics.scheduler, "dynamic");
        assert_eq!(out.metrics.per_chip_seconds.len(), 3);
    }

    #[test]
    fn pool_counters_reported() {
        let (tree, table) = problem();
        let out = run::<f64>(
            &tree,
            &table,
            &RunOptions { chips: 2, batch_capacity: 4, artifacts_dir: None, ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            out.metrics.pool_allocated + out.metrics.pool_reused,
            out.metrics.batches + 1
        );
        assert!(out.metrics.pool_reused > 0, "steady-state streaming must recycle");
        // sequential mode reports per-chip-stream counters: the identity
        // must hold there too (and the forced-static label is surfaced)
        let out = run::<f64>(
            &tree,
            &table,
            &RunOptions {
                chips: 3,
                parallel: false,
                batch_capacity: 4,
                scheduler: SchedulerKind::Dynamic,
                artifacts_dir: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            out.metrics.pool_allocated + out.metrics.pool_reused,
            out.metrics.batches + 1
        );
        assert_eq!(out.metrics.scheduler, "static");
    }

    #[test]
    fn sequential_reports_per_chip_times() {
        let (tree, table) = problem();
        let opts = RunOptions {
            chips: 3,
            parallel: false,
            batch_capacity: 8,
            artifacts_dir: None,
            ..Default::default()
        };
        let out = run::<f64>(&tree, &table, &opts).unwrap();
        assert_eq!(out.metrics.per_chip_seconds.len(), 3);
        assert!(out.metrics.per_chip_seconds.iter().all(|&t| t > 0.0));
        assert!(out.metrics.aggregate_chip_seconds() >= out.metrics.max_chip_seconds());
    }
}
