//! The Layer-3 coordinator: chip partitioning, the streaming pipeline,
//! and the unified run driver over CPU engines and PJRT artifacts.
//!
//! This is the paper's *system* layer: Striped UniFrac splits the stripe
//! set into independent ranges ("chips" — Table 2 runs 128 CPUs / 128
//! GPUs / 4 GPUs), each chip folds every embedding batch into its own
//! stripe accumulators, and the leader assembles the condensed matrix.
//!
//! PJRT clients are thread-bound (`Rc` internally), so simulated chips
//! are described by plain-data [`ChipSpec`]s; each worker thread
//! constructs its own backend (its own PJRT client + compiled artifact —
//! exactly one "device context" per chip, as on a real cluster).

pub mod metrics;
pub mod partition;
pub mod pipeline;

pub use metrics::RunMetrics;
pub use partition::{plan_chips, ChipPlan, ChipSpec};
pub use pipeline::{
    run_chips_parallel, run_chips_parallel_each, run_chips_sequential, run_chips_sequential_each,
};

// The coordinator consumed its own `RunOptions` until the `UniFracJob`
// redesign; it now runs the canonical `api::JobSpec` directly, and the
// old name survives as an alias.
pub use crate::api::{Backend, JobSpec};
pub type RunOptions = JobSpec;

use crate::error::Result;
use crate::matrix::{CondensedMatrix, DistMatrixSink, InMemorySink, SinkMeta, StripeBlock};
use crate::runtime::XlaReal;
use crate::table::FeatureTable;
use crate::tree::Phylogeny;
use crate::unifrac::EngineKind;

/// How one chip executes stripe updates — the *lowered* per-chip
/// backend descriptor `plan_chips` derives from a [`JobSpec`] (with the
/// density-aware auto engine already resolved), analogous to the exec
/// layer's `WorkerSpec`.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Pure-rust CPU engine (the paper's CPU columns).
    Cpu { engine: EngineKind, block_k: usize },
    /// AOT artifact via PJRT (the paper's GPU code path, CPU-executed
    /// here; `engine` selects the artifact flavor, e.g. "pallas_tiled"
    /// or "jnp"). `resident` keeps accumulators device-side between
    /// batches (EXPERIMENTS.md §Perf).
    Pjrt { engine: String, resident: bool },
}

impl BackendSpec {
    pub fn cpu_tiled() -> Self {
        BackendSpec::Cpu { engine: EngineKind::Tiled, block_k: 64 }
    }

    pub fn is_pjrt(&self) -> bool {
        matches!(self, BackendSpec::Pjrt { .. })
    }
}

/// Run output: the distance matrix plus run accounting.
pub struct RunOutput {
    pub dm: CondensedMatrix,
    pub metrics: RunMetrics,
}

/// Top-level driver: resolve the backend, plan chips, execute the
/// pipeline, assemble in RAM.
///
/// Since the ISSUE-5 sink rework this is [`run_to_sink`] with an
/// [`InMemorySink`] behind it — chip blocks are finalized into the
/// condensed matrix as they finish instead of accumulating in a block
/// list first; path-producing callers swap in an out-of-core sink and
/// never materialize the matrix at all.
pub fn run<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    opts: &JobSpec,
) -> Result<RunOutput> {
    crate::unifrac::compute::reject_stripe_range(opts)?;
    let backend = opts.resolve_backend_spec(tree, table)?;
    let plan = plan_chips::<R>(table.n_samples(), opts, &backend)?;
    let mut sink = InMemorySink::new(SinkMeta {
        n_samples: table.n_samples(),
        padded_n: plan.padded_n,
        metric: opts.metric,
        fp_bytes: R::BYTES,
        sample_ids: table.sample_ids().to_vec(),
    })?;
    let metrics = run_planned_to_sink::<R>(tree, table, &plan, opts, &mut sink)?;
    let dm = DistMatrixSink::<R>::take_matrix(&mut sink)
        .expect("in-memory sink holds the matrix until taken");
    Ok(RunOutput { dm, metrics })
}

/// As [`run`], but flushing every finished chip block into `sink`
/// instead of assembling in RAM — the coordinator half of the
/// out-of-core path (`UniFracJob::run_to_path`). The sink must have
/// been created for this run's geometry (`plan_chips` padding).
pub fn run_to_sink<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    opts: &JobSpec,
    sink: &mut dyn DistMatrixSink<R>,
) -> Result<RunMetrics> {
    crate::unifrac::compute::reject_stripe_range(opts)?;
    let backend = opts.resolve_backend_spec(tree, table)?;
    let plan = plan_chips::<R>(table.n_samples(), opts, &backend)?;
    run_planned_to_sink::<R>(tree, table, &plan, opts, sink)
}

/// Shared tail of [`run`]/[`run_to_sink`]: execute the planned chips,
/// streaming finished blocks into the sink, then finalize it (the
/// coverage validation that used to live in
/// `CondensedMatrix::from_stripes`). `pub(crate)` so callers that
/// already planned (to size the sink — `UniFracJob::run_to_path`) do
/// not pay the backend resolution and density walk a second time.
pub(crate) fn run_planned_to_sink<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    plan: &ChipPlan,
    opts: &JobSpec,
    sink: &mut dyn DistMatrixSink<R>,
) -> Result<RunMetrics> {
    let mut emit = |b: StripeBlock<R>| sink.put_block(&b);
    let mut metrics = if opts.parallel {
        run_chips_parallel_each::<R>(tree, table, plan, opts, &mut emit)?
    } else {
        run_chips_sequential_each::<R>(tree, table, plan, opts, &mut emit)?
    };
    let t0 = std::time::Instant::now();
    sink.finish()?;
    metrics.seconds_assemble = t0.elapsed().as_secs_f64();
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SchedulerKind;
    use crate::synth::SynthSpec;
    use crate::unifrac::{compute_unifrac, ComputeOptions};

    fn problem() -> (Phylogeny, FeatureTable) {
        SynthSpec { n_samples: 30, n_features: 200, density: 0.05, ..Default::default() }
            .generate()
    }

    #[test]
    fn coordinator_matches_plain_compute_cpu() {
        let (tree, table) = problem();
        let reference = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { batch_capacity: 8, ..Default::default() },
        )
        .unwrap();
        for chips in [1usize, 2, 5] {
            for parallel in [false, true] {
                let opts = RunOptions {
                    chips,
                    parallel,
                    batch_capacity: 8,
                    artifacts_dir: None,
                    ..Default::default()
                };
                let out = run::<f64>(&tree, &table, &opts).unwrap();
                let diff = out.dm.max_abs_diff(&reference);
                assert!(diff < 1e-12, "chips={chips} parallel={parallel}: {diff}");
                assert_eq!(out.metrics.per_chip_seconds.len(), chips.min(out.metrics.n_stripes));
            }
        }
    }

    #[test]
    fn dynamic_scheduler_matches_static() {
        let (tree, table) = problem();
        let reference = run::<f64>(
            &tree,
            &table,
            &RunOptions { chips: 3, batch_capacity: 8, artifacts_dir: None, ..Default::default() },
        )
        .unwrap();
        let out = run::<f64>(
            &tree,
            &table,
            &RunOptions {
                chips: 3,
                batch_capacity: 8,
                scheduler: SchedulerKind::Dynamic,
                artifacts_dir: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(out.dm.max_abs_diff(&reference.dm) < 1e-10);
        assert_eq!(out.metrics.scheduler, "dynamic");
        assert_eq!(out.metrics.per_chip_seconds.len(), 3);
    }

    #[test]
    fn pool_counters_reported() {
        let (tree, table) = problem();
        let out = run::<f64>(
            &tree,
            &table,
            &RunOptions { chips: 2, batch_capacity: 4, artifacts_dir: None, ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            out.metrics.pool_allocated + out.metrics.pool_reused,
            out.metrics.batches + 1
        );
        assert!(out.metrics.pool_reused > 0, "steady-state streaming must recycle");
        // sequential mode reports per-chip-stream counters: the identity
        // must hold there too (and the forced-static label is surfaced)
        let out = run::<f64>(
            &tree,
            &table,
            &RunOptions {
                chips: 3,
                parallel: false,
                batch_capacity: 4,
                scheduler: SchedulerKind::Dynamic,
                artifacts_dir: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            out.metrics.pool_allocated + out.metrics.pool_reused,
            out.metrics.batches + 1
        );
        assert_eq!(out.metrics.scheduler, "static");
    }

    #[test]
    fn sequential_reports_per_chip_times() {
        let (tree, table) = problem();
        let opts = RunOptions {
            chips: 3,
            parallel: false,
            batch_capacity: 8,
            artifacts_dir: None,
            ..Default::default()
        };
        let out = run::<f64>(&tree, &table, &opts).unwrap();
        assert_eq!(out.metrics.per_chip_seconds.len(), 3);
        assert!(out.metrics.per_chip_seconds.iter().all(|&t| t > 0.0));
        assert!(out.metrics.aggregate_chip_seconds() >= out.metrics.max_chip_seconds());
    }
}
