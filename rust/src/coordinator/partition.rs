//! Chip planning: map the stripe set onto simulated devices.

use super::{BackendSpec, JobSpec};
use crate::error::{Error, Result};
use crate::matrix::total_stripes;
use crate::runtime::{ArtifactQuery, Manifest, XlaReal};

/// One simulated chip: a stripe range plus its backend. Plain data so it
/// can cross threads (PJRT clients are constructed per-thread).
#[derive(Clone, Debug)]
pub struct ChipSpec {
    pub chip_id: usize,
    /// First global stripe this chip owns.
    pub start: usize,
    /// Stripes owned (trimmed to this count at finish).
    pub count: usize,
    pub backend: BackendSpec,
}

/// The full plan for one run.
#[derive(Clone, Debug)]
pub struct ChipPlan {
    /// Padded sample-chunk width.
    pub padded_n: usize,
    /// Total stripes to cover (padded_n / 2).
    pub n_stripes: usize,
    /// Artifact name (PJRT backends; informational).
    pub artifact: Option<String>,
    /// Stripe-block height the backend computes per invocation (PJRT
    /// artifacts have a fixed S; CPU engines use exactly `count`).
    pub block_stripes: usize,
    /// Embedding rows per batch: the artifact's fixed E for PJRT
    /// backends, `opts.batch_capacity` for CPU engines.
    pub batch_capacity: usize,
    pub chips: Vec<ChipSpec>,
}

/// Build the chip plan for `n_samples` under `opts`, with `backend`
/// already resolved from the job spec (the coordinator resolves the
/// density-aware auto engine once, before planning).
///
/// CPU backends pad via the spec's shared padding rule
/// (`JobSpec::padded_width`); PJRT backends pad to the selected
/// artifact's chunk width (and verify the problem fits — one artifact
/// chunk is the unit of this reproduction; larger sample counts use the
/// CPU engines, as Table 2's scale does in the benches).
pub fn plan_chips<R: XlaReal>(
    n_samples: usize,
    opts: &JobSpec,
    backend: &BackendSpec,
) -> Result<ChipPlan> {
    if n_samples < 2 {
        return Err(Error::Shape("need >= 2 samples".into()));
    }
    let dtype = if R::BYTES == 4 { "float32" } else { "float64" };
    let (padded, artifact, block_stripes, batch_capacity) = match backend {
        BackendSpec::Cpu { engine, .. } => {
            let padded = opts.padded_width(*engine, n_samples);
            (padded, None, 0, opts.batch_capacity.max(1))
        }
        BackendSpec::Pjrt { engine, .. } => {
            let dir = opts
                .artifacts_dir
                .as_ref()
                .ok_or_else(|| Error::Config("pjrt backend needs artifacts_dir".into()))?;
            let manifest = Manifest::load(dir.join("manifest.json"))?;
            let q = ArtifactQuery::new(opts.metric, dtype, engine, n_samples);
            let a = manifest.select(&q)?;
            (a.n_samples, Some(a.name.clone()), a.n_stripes, a.emb_batch)
        }
    };
    let n_stripes = total_stripes(padded);
    let chips_n = opts.chips.max(1).min(n_stripes);
    let ranges = crate::exec::split_ranges(n_stripes, chips_n);
    let chips = ranges
        .into_iter()
        .enumerate()
        .map(|(chip_id, (start, count))| ChipSpec {
            chip_id,
            start,
            count,
            backend: backend.clone(),
        })
        .collect();
    Ok(ChipPlan { padded_n: padded, n_stripes, artifact, block_stripes, batch_capacity, chips })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunOptions;
    use crate::unifrac::Metric;

    #[test]
    fn cpu_plan_covers_all_stripes() {
        let opts = RunOptions { chips: 4, artifacts_dir: None, ..Default::default() };
        let plan = plan_chips::<f64>(100, &opts, &BackendSpec::cpu_tiled()).unwrap();
        assert!(plan.padded_n >= 100);
        assert_eq!(plan.n_stripes, plan.padded_n / 2);
        let covered: usize = plan.chips.iter().map(|c| c.count).sum();
        assert_eq!(covered, plan.n_stripes);
        assert_eq!(plan.chips.len(), 4);
        assert!(plan.artifact.is_none());
        // contiguous, ordered
        let mut next = 0;
        for c in &plan.chips {
            assert_eq!(c.start, next);
            next += c.count;
        }
    }

    #[test]
    fn more_chips_than_stripes_clamped() {
        let opts = RunOptions { chips: 1000, artifacts_dir: None, ..Default::default() };
        let plan = plan_chips::<f64>(10, &opts, &BackendSpec::cpu_tiled()).unwrap();
        assert!(plan.chips.len() <= plan.n_stripes);
    }

    #[test]
    fn pjrt_plan_uses_artifact_geometry() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let backend = BackendSpec::Pjrt { engine: "pallas_tiled".into(), resident: false };
        let opts = RunOptions {
            metric: Metric::WeightedNormalized,
            artifacts_dir: Some(dir),
            ..Default::default()
        };
        let plan = plan_chips::<f64>(50, &opts, &backend).unwrap();
        assert!(plan.padded_n >= 50);
        assert!(plan.artifact.is_some());
        assert!(plan.block_stripes > 0);
    }

    #[test]
    fn pjrt_plan_too_large_errors() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let backend = BackendSpec::Pjrt { engine: "pallas_tiled".into(), resident: false };
        let opts = RunOptions { artifacts_dir: Some(dir), ..Default::default() };
        assert!(plan_chips::<f64>(1_000_000, &opts, &backend).is_err());
    }
}
