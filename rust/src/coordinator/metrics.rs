//! Run accounting: what the coordinator measured, ready for reports.

use crate::util::json::{obj, Json};

/// Metrics of one coordinator run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub backend: String,
    /// Stripe scheduling strategy ("static" | "dynamic").
    pub scheduler: String,
    /// SIMD kernel path the CPU engines executed ("scalar" | "avx2" |
    /// "neon"); "scalar" for PJRT-only runs and scalar-reference
    /// engines.
    pub kernel_path: String,
    pub artifact: Option<String>,
    pub n_samples: usize,
    pub padded_n: usize,
    pub n_stripes: usize,
    pub embeddings: usize,
    pub batches: usize,
    /// Batch buffers newly allocated by the exec pool (steady-state
    /// streaming keeps this at the in-flight window — the ISSUE-1
    /// zero-per-batch-allocation acceptance counter).
    pub pool_allocated: usize,
    /// Batch acquisitions served by recycling a returned buffer.
    pub pool_reused: usize,
    /// `u64` words packed by the bit-packed unweighted engine (0 on
    /// scalar/PJRT runs).
    pub packed_words: u64,
    /// 256-entry branch-length LUTs built by the bit-packed engine.
    pub lut_builds: u64,
    /// Base CSR nonzeros built by the sparse weighted engine (0
    /// otherwise).
    pub csr_nnz: u64,
    /// Embedding rows the sparse engine classified below its density
    /// threshold.
    pub rows_sparse: u64,
    /// Embedding rows at or above the sparse threshold.
    pub rows_dense: u64,
    /// Observed mean row density over the sparse engine's CSR builds
    /// (padded chunk width — slightly below `embed_density` when the
    /// sample axis is padded).
    pub csr_density: f64,
    /// Mean embedding-row density measured by the producer stream over
    /// the real sample columns (the auto-selection domain).
    pub embed_density: f64,
    /// GPU adapter name when the gpu engine ran ("vdev" for the
    /// deterministic virtual device); empty for CPU engines.
    pub gpu_adapter: String,
    /// Human-readable note recorded when `engine = auto` wanted the GPU
    /// but no adapter was present and a CPU engine ran instead; empty
    /// when no fallback happened.
    pub gpu_fallback: String,
    /// Device dispatches issued by the gpu engine (one per embedding
    /// batch per stripe block); 0 for CPU engines.
    pub gpu_dispatches: u64,
    /// Bytes staged host-to-device by the gpu engine (column-major
    /// duplicated-sample embeddings + branch lengths); 0 for CPU
    /// engines.
    pub gpu_bytes_staged: u64,
    /// Wall time each chip spent in the stripe phase. In sequential mode
    /// these are true isolated per-chip measurements (the Table-2 "per
    /// chip" row); in parallel mode they overlap.
    pub per_chip_seconds: Vec<f64>,
    /// Producer (embedding generation) time, seconds.
    pub seconds_embed: f64,
    /// End-to-end stripe phase, seconds.
    pub seconds_total: f64,
    /// Sink-finalize time, seconds. Since the ISSUE-5 sink rework,
    /// per-entry distance finalization happens inside the flush as each
    /// block completes (counted in the chip/stripe times above), so
    /// this measures only the final coverage validation + sync — expect
    /// it near zero where the pre-sink "assembly" pass used to
    /// dominate.
    pub seconds_assemble: f64,
}

impl RunMetrics {
    /// Sum of chip times — the paper's "aggregated" row (chip-hours).
    pub fn aggregate_chip_seconds(&self) -> f64 {
        self.per_chip_seconds.iter().sum()
    }

    /// Slowest chip — the critical path in a perfectly parallel run.
    pub fn max_chip_seconds(&self) -> f64 {
        self.per_chip_seconds.iter().fold(0.0, |a, &b| a.max(b))
    }

    /// Stripe updates per second ((embedding, stripe, sample) triples).
    pub fn updates_per_second(&self) -> f64 {
        if self.seconds_total <= 0.0 {
            return 0.0;
        }
        (self.embeddings as f64 * self.n_stripes as f64 * self.padded_n as f64)
            / self.seconds_total
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("backend", Json::from(self.backend.as_str())),
            ("scheduler", Json::from(self.scheduler.as_str())),
            ("kernel_path", Json::from(self.kernel_path.as_str())),
            (
                "artifact",
                self.artifact.as_deref().map(Json::from).unwrap_or(Json::Null),
            ),
            ("n_samples", Json::from(self.n_samples)),
            ("padded_n", Json::from(self.padded_n)),
            ("n_stripes", Json::from(self.n_stripes)),
            ("embeddings", Json::from(self.embeddings)),
            ("batches", Json::from(self.batches)),
            ("pool_allocated", Json::from(self.pool_allocated)),
            ("pool_reused", Json::from(self.pool_reused)),
            ("packed_words", Json::from(self.packed_words as usize)),
            ("lut_builds", Json::from(self.lut_builds as usize)),
            ("csr_nnz", Json::from(self.csr_nnz as usize)),
            ("rows_sparse", Json::from(self.rows_sparse as usize)),
            ("rows_dense", Json::from(self.rows_dense as usize)),
            ("csr_density", Json::from(self.csr_density)),
            ("embed_density", Json::from(self.embed_density)),
            ("gpu_adapter", Json::from(self.gpu_adapter.as_str())),
            ("gpu_fallback", Json::from(self.gpu_fallback.as_str())),
            ("gpu_dispatches", Json::from(self.gpu_dispatches as usize)),
            ("gpu_bytes_staged", Json::from(self.gpu_bytes_staged as usize)),
            (
                "per_chip_seconds",
                Json::Arr(self.per_chip_seconds.iter().map(|&t| Json::Num(t)).collect()),
            ),
            ("seconds_embed", Json::from(self.seconds_embed)),
            ("seconds_total", Json::from(self.seconds_total)),
            ("seconds_assemble", Json::from(self.seconds_assemble)),
            ("updates_per_second", Json::from(self.updates_per_second())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let m = RunMetrics {
            per_chip_seconds: vec![1.0, 3.0, 2.0],
            embeddings: 10,
            n_stripes: 4,
            padded_n: 8,
            seconds_total: 2.0,
            ..Default::default()
        };
        assert_eq!(m.aggregate_chip_seconds(), 6.0);
        assert_eq!(m.max_chip_seconds(), 3.0);
        assert_eq!(m.updates_per_second(), 160.0);
    }

    #[test]
    fn json_roundtrip() {
        let m = RunMetrics {
            backend: "cpu/tiled".into(),
            scheduler: "dynamic".into(),
            kernel_path: "avx2".into(),
            batches: 3,
            pool_allocated: 2,
            pool_reused: 7,
            packed_words: 1024,
            lut_builds: 16,
            csr_nnz: 200,
            rows_sparse: 30,
            rows_dense: 2,
            csr_density: 0.125,
            embed_density: 0.11,
            ..Default::default()
        };
        let j = m.to_json().dump();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("batches").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("artifact").unwrap(), &Json::Null);
        assert_eq!(parsed.get("scheduler").unwrap().as_str(), Some("dynamic"));
        assert_eq!(parsed.get("kernel_path").unwrap().as_str(), Some("avx2"));
        assert_eq!(parsed.get("pool_reused").unwrap().as_usize(), Some(7));
        assert_eq!(parsed.get("packed_words").unwrap().as_usize(), Some(1024));
        assert_eq!(parsed.get("lut_builds").unwrap().as_usize(), Some(16));
        assert_eq!(parsed.get("csr_nnz").unwrap().as_usize(), Some(200));
        assert_eq!(parsed.get("rows_sparse").unwrap().as_usize(), Some(30));
        assert_eq!(parsed.get("rows_dense").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("csr_density").unwrap().as_f64(), Some(0.125));
        assert_eq!(parsed.get("embed_density").unwrap().as_f64(), Some(0.11));
    }
}
