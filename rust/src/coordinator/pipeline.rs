//! The chip pipeline: thin wrappers over the unified streaming core.
//!
//! Two execution modes mirror how the paper runs Table 2:
//! * **sequential** — each chip runs in isolation and is timed
//!   individually (the paper's per-chip rows; also the only way to get
//!   honest per-chip numbers on one host): one single-worker
//!   [`exec::drive`] call per chip;
//! * **parallel** — one producer broadcasts pooled batches to all chip
//!   threads (the deployment topology): one multi-worker
//!   [`exec::drive`] call.
//!
//! The worker construction, channel plumbing and batch pooling all live
//! in `crate::exec`; this module only translates `ChipSpec`s into
//! [`WorkerBuild`]s and folds the exec report into [`RunMetrics`].

use super::metrics::RunMetrics;
use super::partition::ChipPlan;
use super::{BackendSpec, JobSpec};
use crate::error::{Error, Result};
use crate::exec::{self, DriveSpec, ExecReport, SchedulerKind, WorkerBuild, WorkerSpec};
use crate::matrix::StripeBlock;
use crate::runtime::XlaReal;
use crate::table::FeatureTable;
use crate::tree::Phylogeny;

/// Translate a chip backend into an exec worker spec.
fn worker_spec(backend: &BackendSpec, opts: &JobSpec) -> Result<WorkerSpec> {
    match backend {
        BackendSpec::Cpu { engine, block_k } => Ok(WorkerSpec::Cpu {
            engine: *engine,
            block_k: *block_k,
            sparse_threshold: opts.sparse_threshold,
            cpu_features: opts.cpu_features,
        }),
        BackendSpec::Pjrt { engine, resident } => {
            let dir = opts
                .artifacts_dir
                .as_ref()
                .ok_or_else(|| Error::Config("pjrt backend needs artifacts_dir".into()))?;
            Ok(WorkerSpec::Pjrt {
                engine: engine.clone(),
                resident: *resident,
                artifacts_dir: dir.clone(),
            })
        }
    }
}

fn base_metrics(plan: &ChipPlan, opts: &JobSpec, n_samples: usize) -> RunMetrics {
    // Best-effort adapter label for gpu runs: engine selection already
    // validated the adapter request in `JobSpec::resolve_cpu_engine`,
    // so a resolution failure cannot reach this point.
    let gpu_adapter = match plan.chips.first().map(|c| &c.backend) {
        Some(BackendSpec::Cpu { engine, .. }) if *engine == crate::unifrac::EngineKind::Gpu => {
            crate::unifrac::gpu::resolve_adapter(&opts.gpu_adapter)
                .map(|a| a.name)
                .unwrap_or_default()
        }
        _ => String::new(),
    };
    RunMetrics {
        // all chips share one lowered backend; label from the plan
        backend: match plan.chips.first().map(|c| &c.backend) {
            Some(BackendSpec::Cpu { engine, .. }) => {
                if *engine == crate::unifrac::EngineKind::Gpu {
                    format!("gpu/{gpu_adapter}")
                } else {
                    format!("cpu/{}", engine.name())
                }
            }
            Some(BackendSpec::Pjrt { engine, resident }) => {
                format!("pjrt/{engine}{}", if *resident { "+resident" } else { "" })
            }
            None => "cpu".to_string(),
        },
        gpu_adapter,
        scheduler: opts.scheduler.name().to_string(),
        // overwritten by `absorb` with the path the engines actually
        // executed; PJRT-only runs keep the scalar label
        kernel_path: "scalar".to_string(),
        artifact: plan.artifact.clone(),
        n_samples,
        padded_n: plan.padded_n,
        n_stripes: plan.n_stripes,
        ..Default::default()
    }
}

fn drive_spec(plan: &ChipPlan, opts: &JobSpec, workers: Vec<WorkerBuild>) -> DriveSpec {
    DriveSpec {
        metric: opts.metric,
        padded_n: plan.padded_n,
        batch_capacity: plan.batch_capacity,
        queue_depth: opts.queue_depth.max(1),
        pool_depth: opts.pool_depth,
        scheduler: opts.scheduler,
        chunk_stripes: 0,
        workers,
    }
}

/// Fold one drive report into the run metrics. Values are per-stream:
/// parallel mode has exactly one stream; sequential mode re-streams per
/// chip with identical counts, so the last chip's numbers represent any
/// of them (keeping the `pool_allocated + pool_reused == batches + 1`
/// invariant intact either way). The engine work counters follow the
/// same convention — every sequential chip converts the identical batch
/// stream, so its `packed_words`/`csr_nnz`/row-classification counts
/// (and the densities) equal any other chip's; these are per-stream
/// figures, not sums over chips.
fn absorb(metrics: &mut RunMetrics, rep: &ExecReport) {
    metrics.embeddings = rep.embeddings;
    metrics.batches = rep.batches;
    metrics.seconds_embed = rep.seconds_embed;
    metrics.pool_allocated = rep.pool.allocated;
    metrics.pool_reused = rep.pool.reused;
    metrics.packed_words = rep.engine_stats.packed_words;
    metrics.lut_builds = rep.engine_stats.lut_builds;
    metrics.csr_nnz = rep.engine_stats.csr_nnz;
    metrics.rows_sparse = rep.engine_stats.rows_sparse;
    metrics.rows_dense = rep.engine_stats.rows_dense;
    metrics.csr_density = rep.engine_stats.csr_density();
    metrics.embed_density = rep.embed_density;
    metrics.gpu_dispatches = rep.engine_stats.gpu_dispatches;
    metrics.gpu_bytes_staged = rep.engine_stats.gpu_bytes_staged;
    metrics.kernel_path = rep.engine_stats.kernel_path.name().to_string();
}

/// Sequential mode: run each chip in isolation, timing it precisely.
/// Each chip re-streams the embeddings through its own single-worker
/// pipeline (that isolation is the point of the measurement mode).
/// Finished chip blocks stream to `emit` the moment the chip's drive
/// completes — the ISSUE-5 flush point: with an out-of-core sink behind
/// `emit`, only ONE chip's stripe scratch is ever resident.
pub fn run_chips_sequential_each<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    plan: &ChipPlan,
    opts: &JobSpec,
    emit: &mut dyn FnMut(StripeBlock<R>) -> Result<()>,
) -> Result<RunMetrics> {
    let t_all = std::time::Instant::now();
    let mut metrics = base_metrics(plan, opts, table.n_samples());
    // isolated per-chip timing always runs fixed ranges; report what
    // actually executed rather than the requested scheduler
    metrics.scheduler = SchedulerKind::Static.name().to_string();
    for spec in &plan.chips {
        let t0 = std::time::Instant::now();
        let workers = vec![WorkerBuild {
            spec: worker_spec(&spec.backend, opts)?,
            range: Some((spec.start, spec.count)),
        }];
        // isolated timing wants the plain fixed-range path
        let mut dspec = drive_spec(plan, opts, workers);
        dspec.scheduler = SchedulerKind::Static;
        let rep = exec::drive_each::<R>(tree, table, &dspec, emit)?;
        metrics.per_chip_seconds.push(t0.elapsed().as_secs_f64());
        absorb(&mut metrics, &rep);
    }
    metrics.seconds_total = t_all.elapsed().as_secs_f64();
    Ok(metrics)
}

/// As [`run_chips_sequential_each`], collecting the blocks (legacy
/// shape for callers that assemble in RAM).
pub fn run_chips_sequential<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    plan: &ChipPlan,
    opts: &JobSpec,
) -> Result<(Vec<StripeBlock<R>>, RunMetrics)> {
    let mut blocks = Vec::with_capacity(plan.chips.len());
    let metrics = run_chips_sequential_each(tree, table, plan, opts, &mut |b| {
        blocks.push(b);
        Ok(())
    })?;
    Ok((blocks, metrics))
}

/// Parallel mode: one producer, all chips as workers of a single
/// [`exec::drive_each`] call. Under the static scheduler each chip
/// keeps its planned contiguous range; under the dynamic scheduler CPU
/// chips steal stripe chunks (PJRT chips keep their fixed-height
/// ranges). Finished blocks stream to `emit` in worker join order.
pub fn run_chips_parallel_each<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    plan: &ChipPlan,
    opts: &JobSpec,
    emit: &mut dyn FnMut(StripeBlock<R>) -> Result<()>,
) -> Result<RunMetrics> {
    let t_all = std::time::Instant::now();
    let mut metrics = base_metrics(plan, opts, table.n_samples());
    let workers = plan
        .chips
        .iter()
        .map(|spec| {
            let wspec = worker_spec(&spec.backend, opts)?;
            let pinned = opts.scheduler == SchedulerKind::Static
                || matches!(wspec, WorkerSpec::Pjrt { .. });
            Ok(WorkerBuild {
                spec: wspec,
                range: pinned.then_some((spec.start, spec.count)),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let dspec = drive_spec(plan, opts, workers);
    let rep = exec::drive_each::<R>(tree, table, &dspec, emit)?;
    metrics.per_chip_seconds = rep.per_worker_seconds.clone();
    absorb(&mut metrics, &rep);
    metrics.seconds_total = t_all.elapsed().as_secs_f64();
    Ok(metrics)
}

/// As [`run_chips_parallel_each`], collecting the blocks (legacy shape
/// for callers that assemble in RAM).
pub fn run_chips_parallel<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    plan: &ChipPlan,
    opts: &JobSpec,
) -> Result<(Vec<StripeBlock<R>>, RunMetrics)> {
    let mut blocks = Vec::new();
    let metrics = run_chips_parallel_each(tree, table, plan, opts, &mut |b| {
        blocks.push(b);
        Ok(())
    })?;
    Ok((blocks, metrics))
}
