//! The streaming chip pipeline: embedding producer → chip workers.
//!
//! Two execution modes mirror how the paper runs Table 2:
//! * **sequential** — each chip runs in isolation and is timed
//!   individually (the paper's per-chip rows; also the only way to get
//!   honest per-chip numbers on one host);
//! * **parallel** — one producer broadcasts batches through bounded
//!   queues to all chip threads (the deployment topology; backpressure
//!   keeps peak memory at `chips · queue_depth` batches).

use super::metrics::RunMetrics;
use super::partition::{ChipPlan, ChipSpec};
use super::{BackendSpec, RunOptions};
use crate::embed::{generate_embeddings, EmbBatch};
use crate::error::{Error, Result};
use crate::matrix::StripeBlock;
use crate::runtime::{ArtifactQuery, ResidentUpdater, Runtime, StripeExecutor, XlaReal};
use crate::table::FeatureTable;
use crate::tree::Phylogeny;
use crate::unifrac::{make_engine, Metric, StripeEngine};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;

/// One chip's execution state. Built *inside* the worker thread because
/// PJRT clients are not `Send` — each chip owns its device context,
/// exactly like a rank in the paper's distributed runs.
enum ChipWorker<R: XlaReal> {
    Cpu {
        engine: Box<dyn StripeEngine<R>>,
        metric: Metric,
        block: StripeBlock<R>,
    },
    PjrtOneShot {
        exec: StripeExecutor,
        // runtime kept alive for the executable's client
        _runtime: Box<Runtime>,
        block: StripeBlock<R>,
        count: usize,
    },
    PjrtResident {
        upd: ResidentUpdater<R>,
        _runtime: Box<Runtime>,
        padded: usize,
        start: usize,
        s_artifact: usize,
        count: usize,
    },
}

impl<R: XlaReal> ChipWorker<R> {
    fn build(spec: &ChipSpec, plan: &ChipPlan, opts: &RunOptions) -> Result<Self> {
        match &spec.backend {
            BackendSpec::Cpu { engine, block_k } => Ok(ChipWorker::Cpu {
                engine: make_engine::<R>(*engine, *block_k),
                metric: opts.metric,
                block: StripeBlock::new(plan.padded_n, spec.start, spec.count),
            }),
            BackendSpec::Pjrt { engine, resident } => {
                let dir = opts
                    .artifacts_dir
                    .as_ref()
                    .ok_or_else(|| Error::Config("pjrt backend needs artifacts_dir".into()))?;
                let runtime = Box::new(Runtime::open(dir)?);
                let dtype = if R::BYTES == 4 { "float32" } else { "float64" };
                let q = ArtifactQuery::new(opts.metric, dtype, engine, plan.padded_n);
                let exec = runtime.executor(&q)?;
                let s_artifact = exec.artifact().n_stripes;
                // the artifact computes a fixed S-block from `start`;
                // rows beyond `count` are trimmed at finish
                let block = StripeBlock::new(plan.padded_n, spec.start, s_artifact);
                if *resident {
                    let upd = exec.resident(&block)?;
                    Ok(ChipWorker::PjrtResident {
                        upd,
                        _runtime: runtime,
                        padded: plan.padded_n,
                        start: spec.start,
                        s_artifact,
                        count: spec.count,
                    })
                } else {
                    Ok(ChipWorker::PjrtOneShot {
                        exec,
                        _runtime: runtime,
                        block,
                        count: spec.count,
                    })
                }
            }
        }
    }

    fn consume(&mut self, batch: &EmbBatch<R>) -> Result<()> {
        match self {
            ChipWorker::Cpu { engine, metric, block, .. } => {
                engine.apply(*metric, batch, block);
                Ok(())
            }
            ChipWorker::PjrtOneShot { exec, block, .. } => exec.update(batch, block),
            ChipWorker::PjrtResident { upd, .. } => upd.update(batch),
        }
    }

    /// Produce the chip's stripe block, trimmed to its owned range.
    fn finish(self) -> Result<StripeBlock<R>> {
        match self {
            ChipWorker::Cpu { block, .. } => Ok(block),
            ChipWorker::PjrtOneShot { block, count, .. } => Ok(trim(block, count)),
            ChipWorker::PjrtResident { upd, padded, start, s_artifact, count, .. } => {
                let mut block = StripeBlock::new(padded, start, s_artifact);
                upd.finish(&mut block)?;
                Ok(trim(block, count))
            }
        }
    }
}

/// Keep only the first `count` stripes of a block (PJRT artifacts compute
/// a fixed-height S-block; the chip owns a possibly shorter range).
fn trim<R: XlaReal>(block: StripeBlock<R>, count: usize) -> StripeBlock<R> {
    if count >= block.n_stripes() {
        return block;
    }
    let mut out = StripeBlock::new(block.n_samples(), block.start(), count);
    for s in 0..count {
        let (num, den) = out.rows_mut(s);
        num.copy_from_slice(block.num_row(s));
        den.copy_from_slice(block.den_row(s));
    }
    out
}

fn base_metrics(plan: &ChipPlan, opts: &RunOptions, n_samples: usize) -> RunMetrics {
    RunMetrics {
        backend: match &opts.backend {
            BackendSpec::Cpu { engine, .. } => format!("cpu/{}", engine.name()),
            BackendSpec::Pjrt { engine, resident } => {
                format!("pjrt/{engine}{}", if *resident { "+resident" } else { "" })
            }
        },
        artifact: plan.artifact.clone(),
        n_samples,
        padded_n: plan.padded_n,
        n_stripes: plan.n_stripes,
        ..Default::default()
    }
}

/// Sequential mode: run each chip in isolation, timing it precisely.
pub fn run_chips_sequential<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    plan: &ChipPlan,
    opts: &RunOptions,
) -> Result<(Vec<StripeBlock<R>>, RunMetrics)> {
    let t_all = std::time::Instant::now();
    let mut metrics = base_metrics(plan, opts, table.n_samples());
    let mut blocks = Vec::with_capacity(plan.chips.len());
    for spec in &plan.chips {
        let t0 = std::time::Instant::now();
        let mut worker = ChipWorker::<R>::build(spec, plan, opts)?;
        let mut err: Option<Error> = None;
        let mut batches = 0usize;
        let produced = generate_embeddings::<R>(
            tree,
            table,
            opts.metric.embedding_kind(),
            plan.padded_n,
            plan.batch_capacity,
            |batch| {
                if err.is_none() {
                    if let Err(e) = worker.consume(batch) {
                        err = Some(e);
                    }
                    batches += 1;
                }
            },
        )?;
        if let Some(e) = err {
            return Err(e);
        }
        blocks.push(worker.finish()?);
        metrics.per_chip_seconds.push(t0.elapsed().as_secs_f64());
        metrics.embeddings = produced;
        metrics.batches = batches;
    }
    metrics.seconds_total = t_all.elapsed().as_secs_f64();
    Ok((blocks, metrics))
}

/// Parallel mode: one producer, `chips` worker threads, bounded queues.
pub fn run_chips_parallel<R: XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    plan: &ChipPlan,
    opts: &RunOptions,
) -> Result<(Vec<StripeBlock<R>>, RunMetrics)> {
    let t_all = std::time::Instant::now();
    let mut metrics = base_metrics(plan, opts, table.n_samples());
    let result: Result<Vec<(StripeBlock<R>, f64)>> = std::thread::scope(|scope| {
        let mut senders = Vec::with_capacity(plan.chips.len());
        let mut handles = Vec::with_capacity(plan.chips.len());
        for spec in &plan.chips {
            let (tx, rx) = sync_channel::<Arc<EmbBatch<R>>>(opts.queue_depth.max(1));
            senders.push(tx);
            handles.push(scope.spawn(move || -> Result<(StripeBlock<R>, f64)> {
                let t0 = std::time::Instant::now();
                let mut worker = ChipWorker::<R>::build(spec, plan, opts)?;
                while let Ok(batch) = rx.recv() {
                    worker.consume(&batch)?;
                }
                Ok((worker.finish()?, t0.elapsed().as_secs_f64()))
            }));
        }
        let t_embed = std::time::Instant::now();
        let mut batches = 0usize;
        let produced = generate_embeddings::<R>(
            tree,
            table,
            opts.metric.embedding_kind(),
            plan.padded_n,
            plan.batch_capacity,
            |batch| {
                let shared = Arc::new(batch.clone());
                for tx in &senders {
                    // a closed queue means the worker errored; its Err
                    // surfaces at join
                    let _ = tx.send(Arc::clone(&shared));
                }
                batches += 1;
            },
        )?;
        drop(senders);
        metrics.seconds_embed = t_embed.elapsed().as_secs_f64();
        metrics.embeddings = produced;
        metrics.batches = batches;
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| Error::invalid("chip worker panicked"))?)
            .collect()
    });
    let pairs = result?;
    let mut blocks = Vec::with_capacity(pairs.len());
    for (block, secs) in pairs {
        blocks.push(block);
        metrics.per_chip_seconds.push(secs);
    }
    metrics.seconds_total = t_all.elapsed().as_secs_f64();
    Ok((blocks, metrics))
}
