//! Deterministic fault injection for the stripe fleet.
//!
//! A [`FaultPlan`] is a parsed `--fault` / `UNIFRAC_FAULT` spec — a
//! `;`-separated list of directives, each anchored to a global stripe
//! index so the same spec reproduces the same failure on every run:
//!
//! ```text
//! kill@N            abort the worker whose shard contains stripe N
//!                   (before its partial is written)
//! truncate@N[:B]    chop B bytes (default 16) off the end of the
//!                   partial written by the shard containing stripe N
//! flip@N            flip one payload bit of that shard's partial
//!                   (byte/bit chosen by the seeded PRNG)
//! delay@N:MS        sleep MS milliseconds before computing the shard
//!                   containing stripe N
//! halt@K            supervisor-side: stop the fleet after K shards
//!                   have flushed, leaving a resumable sink behind
//! reject@N          service-side: shed the N-th query request with a
//!                   typed Overloaded error (admission-control test)
//! slowref@N:MS      service-side: sleep MS before loading the
//!                   reference set for the N-th query request (drives
//!                   the deadline path deterministically)
//! drop-conn@N       service-side: close the client connection of the
//!                   N-th query request without responding
//! ```
//!
//! The supervisor owns the plan: each non-`halt` directive is handed to
//! exactly one worker (the first dispatch whose shard covers its
//! stripe) and never re-sent on retry, so every injected failure fires
//! once and the fleet provably converges. Compute-time directives
//! (`kill`, `delay`) fire inside `UniFracJob::run_partial_range`;
//! artifact directives (`truncate`, `flip`) are applied by the `worker`
//! subcommand to the partial file it just wrote. Service directives
//! (`reject`, `slowref`, `drop-conn`) are owned by `unifrac serve`:
//! their anchor is a 0-based query-request counter, each fires once
//! ([`FaultPlan::take_service_at`]), and they are never handed to
//! workers.

use crate::error::{Error, Result};
use crate::util::prng::Xoshiro256;
use std::fmt;
use std::path::Path;

/// One failure mode, anchored at a stripe (or, for `halt`, a flush count).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Abort the worker process (`std::process::abort`) before it
    /// writes its partial — simulates an OOM kill or node loss.
    Kill,
    /// Truncate this many bytes off the end of the written partial —
    /// simulates a torn write. The checksum must catch it.
    Truncate(usize),
    /// Flip one bit inside the written partial's payload — simulates
    /// bit rot. The checksum must catch it.
    Flip,
    /// Sleep this many milliseconds before computing — simulates a
    /// straggler (drives the supervisor's timeout/re-queue path).
    Delay(u64),
    /// Supervisor-side: stop the whole fleet after the anchor count of
    /// shard flushes, leaving a resumable sink (tests resume).
    Halt,
    /// Service-side: shed the anchor-th query request at admission with
    /// a typed `Overloaded` error, as if the queue were full.
    Reject,
    /// Service-side: sleep this many milliseconds before loading the
    /// reference set for the anchor-th query request — a deterministic
    /// slow-artifact straggler that drives the deadline path.
    SlowRef(u64),
    /// Service-side: close the client connection of the anchor-th query
    /// request without writing a response (tests slow/broken clients).
    DropConn,
}

impl FaultKind {
    /// True for the service-side directives (`reject`, `slowref`,
    /// `drop-conn`): owned by `unifrac serve`, never handed to workers.
    pub fn is_service(&self) -> bool {
        matches!(self, FaultKind::Reject | FaultKind::SlowRef(_) | FaultKind::DropConn)
    }
}

/// A [`FaultKind`] plus its anchor: the global stripe index the
/// directive fires at (`halt`: the number of flushed shards).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultDirective {
    /// What to do.
    pub kind: FaultKind,
    /// Global stripe index (or flush count for [`FaultKind::Halt`]).
    pub at: usize,
}

impl fmt::Display for FaultDirective {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            FaultKind::Kill => write!(f, "kill@{}", self.at),
            FaultKind::Truncate(n) => write!(f, "truncate@{}:{n}", self.at),
            FaultKind::Flip => write!(f, "flip@{}", self.at),
            FaultKind::Delay(ms) => write!(f, "delay@{}:{ms}", self.at),
            FaultKind::Halt => write!(f, "halt@{}", self.at),
            FaultKind::Reject => write!(f, "reject@{}", self.at),
            FaultKind::SlowRef(ms) => write!(f, "slowref@{}:{ms}", self.at),
            FaultKind::DropConn => write!(f, "drop-conn@{}", self.at),
        }
    }
}

/// A parsed, seeded fault-injection plan (see the module docs for the
/// spec grammar). Deterministic: the same spec + seed reproduces the
/// same corruption bytes on every platform.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// The directives, in spec order.
    pub directives: Vec<FaultDirective>,
    /// Seed for the corruption PRNG (bit/byte choice of `flip`).
    pub seed: u64,
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, d) in self.directives.iter().enumerate() {
            if i > 0 {
                write!(f, ";")?;
            }
            write!(f, "{d}")?;
        }
        Ok(())
    }
}

impl FaultPlan {
    /// An empty plan (no directives) with the given seed.
    pub fn empty(seed: u64) -> Self {
        Self { directives: Vec::new(), seed }
    }

    /// Parse a `--fault` spec. Unknown directives, missing anchors and
    /// malformed numbers are typed config errors naming the grammar.
    pub fn parse(spec: &str, seed: u64) -> Result<Self> {
        let mut directives = Vec::new();
        for raw in spec.split(';') {
            let part = raw.trim();
            if part.is_empty() {
                continue;
            }
            let (name, anchor) = part.split_once('@').ok_or_else(|| bad(part, "missing @N"))?;
            let (at_str, arg) = match anchor.split_once(':') {
                Some((a, b)) => (a, Some(b)),
                None => (anchor, None),
            };
            let at: usize = at_str.parse().map_err(|_| bad(part, "anchor must be an integer"))?;
            let kind = match (name, arg) {
                ("kill", None) => FaultKind::Kill,
                ("flip", None) => FaultKind::Flip,
                ("halt", None) => FaultKind::Halt,
                ("truncate", None) => FaultKind::Truncate(16),
                ("truncate", Some(b)) => FaultKind::Truncate(
                    b.parse().map_err(|_| bad(part, "truncate byte count must be an integer"))?,
                ),
                ("delay", Some(ms)) => FaultKind::Delay(
                    ms.parse().map_err(|_| bad(part, "delay milliseconds must be an integer"))?,
                ),
                ("delay", None) => return Err(bad(part, "delay needs @N:MS")),
                ("reject", None) => FaultKind::Reject,
                ("slowref", Some(ms)) => FaultKind::SlowRef(
                    ms.parse()
                        .map_err(|_| bad(part, "slowref milliseconds must be an integer"))?,
                ),
                ("slowref", None) => return Err(bad(part, "slowref needs @N:MS")),
                ("drop-conn", None) => FaultKind::DropConn,
                _ => return Err(bad(part, "unknown directive")),
            };
            directives.push(FaultDirective { kind, at });
        }
        Ok(Self { directives, seed })
    }

    /// True when no directives remain.
    pub fn is_empty(&self) -> bool {
        self.directives.is_empty()
    }

    /// The smallest `halt@K` anchor, if any (supervisor-side stop).
    pub fn halt_after(&self) -> Option<usize> {
        self.directives
            .iter()
            .filter(|d| d.kind == FaultKind::Halt)
            .map(|d| d.at)
            .min()
    }

    /// Remove (and return as an argv-ready spec string) every
    /// worker-side directive whose anchor stripe falls in
    /// `start .. start + count`. `halt` directives are supervisor-owned
    /// and service directives server-owned — neither is ever taken.
    /// Returns `None` when nothing matched — the single-fire guarantee:
    /// a retried shard gets no directives.
    pub fn take_for_range(&mut self, start: usize, count: usize) -> Option<String> {
        let in_range = |d: &FaultDirective| {
            d.kind != FaultKind::Halt
                && !d.kind.is_service()
                && d.at >= start
                && d.at < start + count
        };
        if !self.directives.iter().any(in_range) {
            return None;
        }
        let mut taken = Vec::new();
        self.directives.retain(|d| {
            if in_range(d) {
                taken.push(*d);
                false
            } else {
                true
            }
        });
        Some(FaultPlan { directives: taken, seed: self.seed }.to_string())
    }

    /// Remove and return every service-side directive anchored at
    /// query-request index `at` (0-based admission order). Single-fire:
    /// a directive fires for exactly one request and is then gone, so a
    /// client retry of the same logical query succeeds. Called by
    /// `unifrac serve` once per accepted connection.
    pub fn take_service_at(&mut self, at: usize) -> Vec<FaultKind> {
        let mut taken = Vec::new();
        self.directives.retain(|d| {
            if d.kind.is_service() && d.at == at {
                taken.push(d.kind);
                false
            } else {
                true
            }
        });
        taken
    }

    /// Fire the compute-time directives (`delay`, then `kill`) whose
    /// anchor falls in `start .. start + count`. Called by the partial
    /// compute path, i.e. inside the worker process. `kill` never
    /// returns — it aborts the process, simulating a node loss.
    pub fn apply_compute_faults(&self, start: usize, count: usize) {
        let hits = self
            .directives
            .iter()
            .filter(|d| d.at >= start && d.at < start + count);
        for d in hits.clone() {
            if let FaultKind::Delay(ms) = d.kind {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
        for d in hits {
            if d.kind == FaultKind::Kill {
                eprintln!("fault: kill@{} — aborting worker", d.at);
                std::process::abort();
            }
        }
    }

    /// Fire the artifact directives (`truncate`, `flip`) whose anchor
    /// falls in `start .. start + count` against the partial file at
    /// `path`. `payload_bytes` is the file's numeric payload length
    /// (trailing bytes) — `flip` targets a payload bit so the payload
    /// checksum is what must catch it. Returns a description of each
    /// applied directive (worker log lines).
    pub fn corrupt_artifact(
        &self,
        path: impl AsRef<Path>,
        start: usize,
        count: usize,
        payload_bytes: u64,
    ) -> Result<Vec<String>> {
        let path = path.as_ref();
        let mut applied = Vec::new();
        for d in &self.directives {
            if d.at < start || d.at >= start + count {
                continue;
            }
            match d.kind {
                FaultKind::Truncate(n) => {
                    let f = std::fs::OpenOptions::new().write(true).open(path)?;
                    let len = f.metadata()?.len();
                    let new_len = len.saturating_sub(n as u64);
                    f.set_len(new_len)?;
                    applied.push(format!("truncate@{}: {len} -> {new_len} bytes", d.at));
                }
                FaultKind::Flip => {
                    let mut bytes = std::fs::read(path)?;
                    let len = bytes.len() as u64;
                    if len == 0 {
                        continue;
                    }
                    // deterministic per (seed, anchor): the same spec
                    // flips the same bit on every run
                    let mut prng = Xoshiro256::new(self.seed ^ d.at as u64);
                    let span = payload_bytes.clamp(1, len) as usize;
                    let off = bytes.len() - span + prng.below(span);
                    let bit = prng.below(8) as u32;
                    bytes[off] ^= 1 << bit;
                    std::fs::write(path, &bytes)?;
                    applied.push(format!("flip@{}: bit {bit} of byte {off}", d.at));
                }
                FaultKind::Kill
                | FaultKind::Delay(_)
                | FaultKind::Halt
                | FaultKind::Reject
                | FaultKind::SlowRef(_)
                | FaultKind::DropConn => {}
            }
        }
        Ok(applied)
    }
}

fn bad(part: &str, why: &str) -> Error {
    Error::Config(format!(
        "bad fault directive {part:?}: {why} (grammar: kill@N | truncate@N[:BYTES] | \
         flip@N | delay@N:MS | halt@K | reject@N | slowref@N:MS | drop-conn@N, \
         ';'-separated)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_through_display() {
        let spec = "kill@3;truncate@5:32;flip@7;delay@2:50;halt@1;reject@0;slowref@4:25;drop-conn@6";
        let plan = FaultPlan::parse(spec, 9).unwrap();
        assert_eq!(plan.directives.len(), 8);
        assert_eq!(plan.to_string(), spec);
        assert_eq!(FaultPlan::parse(&plan.to_string(), 9).unwrap(), plan);
        // default truncate byte count
        let t = FaultPlan::parse("truncate@4", 0).unwrap();
        assert_eq!(t.directives[0].kind, FaultKind::Truncate(16));
        // empty spec -> empty plan
        assert!(FaultPlan::parse("", 0).unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ", 0).unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "kill",
            "kill@x",
            "boom@3",
            "delay@3",
            "delay@3:ms",
            "truncate@1:x",
            "slowref@2",
            "slowref@2:ms",
            "reject@1:5",
            "drop-conn@1:5",
        ] {
            let err = FaultPlan::parse(bad, 0).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{bad}: {err}");
            assert!(err.to_string().contains("grammar"), "{bad}: {err}");
        }
    }

    #[test]
    fn take_for_range_is_single_fire_and_leaves_halt() {
        let mut plan = FaultPlan::parse("kill@3;flip@10;halt@2", 1).unwrap();
        // stripe 3 falls in [0, 5): kill taken, flip + halt stay
        let spec = plan.take_for_range(0, 5).unwrap();
        assert_eq!(spec, "kill@3");
        assert_eq!(plan.directives.len(), 2);
        // second dispatch of the same range gets nothing
        assert_eq!(plan.take_for_range(0, 5), None);
        // halt is never handed to a worker
        assert_eq!(plan.take_for_range(0, 100).unwrap(), "flip@10");
        assert_eq!(plan.halt_after(), Some(2));
    }

    #[test]
    fn service_directives_are_server_owned_and_single_fire() {
        let mut plan =
            FaultPlan::parse("reject@1;slowref@1:40;drop-conn@2;kill@1", 0).unwrap();
        // worker dispatch over any range never takes a service directive
        assert_eq!(plan.take_for_range(0, 100).unwrap(), "kill@1");
        assert_eq!(plan.directives.len(), 3);
        // request 0: nothing anchored there
        assert!(plan.take_service_at(0).is_empty());
        // request 1: both directives fire together, then are gone
        let fired = plan.take_service_at(1);
        assert_eq!(fired, vec![FaultKind::Reject, FaultKind::SlowRef(40)]);
        assert!(plan.take_service_at(1).is_empty());
        // request 2: drop-conn fires once
        assert_eq!(plan.take_service_at(2), vec![FaultKind::DropConn]);
        assert!(plan.is_empty());
    }

    #[test]
    fn corrupt_artifact_is_deterministic_and_ranged() {
        let dir = std::env::temp_dir().join(format!("unifrac_fault_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.ufpr");
        let original: Vec<u8> = (0..200u8).collect();

        // out-of-range directives leave the file alone
        std::fs::write(&path, &original).unwrap();
        let plan = FaultPlan::parse("flip@50;truncate@60", 7).unwrap();
        assert!(plan.corrupt_artifact(&path, 0, 10, 64).unwrap().is_empty());
        assert_eq!(std::fs::read(&path).unwrap(), original);

        // flip: exactly one bit differs, in the payload (last 64 bytes),
        // and the same seed flips the same bit again
        let plan = FaultPlan::parse("flip@5", 7).unwrap();
        plan.corrupt_artifact(&path, 0, 10, 64).unwrap();
        let once = std::fs::read(&path).unwrap();
        let diffs: Vec<usize> =
            (0..200).filter(|&i| once[i] != original[i]).collect();
        assert_eq!(diffs.len(), 1);
        assert!(diffs[0] >= 200 - 64, "flip landed outside the payload");
        assert_eq!((once[diffs[0]] ^ original[diffs[0]]).count_ones(), 1);
        std::fs::write(&path, &original).unwrap();
        plan.corrupt_artifact(&path, 0, 10, 64).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), once);

        // truncate chops the tail
        std::fs::write(&path, &original).unwrap();
        let plan = FaultPlan::parse("truncate@5:24", 7).unwrap();
        plan.corrupt_artifact(&path, 0, 10, 64).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), original[..176]);

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compute_faults_outside_range_are_noops() {
        // a kill anchored outside the range must NOT abort this process
        let plan = FaultPlan::parse("kill@99;delay@98:1", 0).unwrap();
        plan.apply_compute_faults(0, 10);
        // in-range delay sleeps (and returns)
        let plan = FaultPlan::parse("delay@3:1", 0).unwrap();
        let t0 = std::time::Instant::now();
        plan.apply_compute_faults(0, 5);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(1));
    }
}
