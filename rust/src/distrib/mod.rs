//! Fault-tolerant multi-process stripe fleet (ISSUE 7 tentpole).
//!
//! Striped UniFrac's stripes are embarrassingly parallel, and PR 4 made
//! stripe partials first-class (`UFPR` files + `merge_partials`). This
//! module adds the missing operational layer for running that split
//! across *processes that fail*: a [`supervise`] loop that shards the
//! stripe space over re-invocations of the `unifrac worker` subcommand,
//! flushes each finished shard into a resumable on-disk sink, and
//! converges on a matrix bit-identical to the single-process run
//! despite killed workers, stragglers and corrupt artifacts.
//!
//! The pieces:
//!
//! * [`supervisor`] — the dispatch/poll loop: per-slot speed tracking
//!   (slower workers get smaller shards), per-shard timeouts, bounded
//!   retry with exponential backoff + jitter, graceful degradation to
//!   in-process compute when spawning fails, and resume from a prior
//!   interrupted run via the sink's coverage state.
//! * [`fault`] — the deterministic fault-injection harness
//!   (`--fault` / `UNIFRAC_FAULT`): kill/truncate/flip/delay/halt
//!   directives anchored to stripe indices, seeded so every failure
//!   reproduces exactly. The property suite in `tests/distrib_faults.rs`
//!   drives it to prove convergence.
//!
//! Integrity: `UFPR` partials and `UFDM` matrices carry CRC32C
//! checksums (format v2); the supervisor treats a checksum rejection as
//! one more retryable shard failure, so torn writes and bit rot are
//! recomputed, never merged. See `docs/distributed.md` for the
//! operator guide and the wire-format/retry-policy reference.

pub mod fault;
pub mod supervisor;

pub use fault::{FaultDirective, FaultKind, FaultPlan};
pub use supervisor::{classify_exit, supervise, Disposition, FleetReport, FleetSpec};
