//! The stripe-fleet supervisor: shard the stripe space across worker
//! *processes*, survive their failures, and converge on the exact
//! single-process matrix.
//!
//! Each worker is a re-invocation of the `unifrac` CLI's `worker`
//! subcommand computing one stripe shard into a checksummed `UFPR`
//! partial. The supervisor polls the fleet, flushes finished shards
//! into a resumable sink, and treats every failure mode uniformly as a
//! retryable shard: a killed worker, a timed-out straggler, and a
//! corrupt partial (CRC32C rejection at load) all re-queue with
//! exponential backoff + jitter onto the surviving workers. Worker
//! speeds are tracked per slot, so a slower worker receives smaller
//! remaining shards (the heterogeneous-fleet policy). If workers cannot
//! be spawned at all, the supervisor degrades gracefully and computes
//! shards in-process — a one-worker local fleet.
//!
//! Bit-identity: the supervisor resolves the job's engine/padding
//! geometry once and pins it on every worker's command line, and each
//! worker computes its shard through the same static-scheduler partial
//! path a single-process run uses — so the merged matrix equals the
//! single-process result exactly (`== 0.0`), per the partial/merge
//! guarantee.

use super::fault::FaultPlan;
use crate::api::{FpWidth, JobSpec, PartialData, PartialResult, UniFracJob};
use crate::error::{Error, Result};
use crate::matrix::{DistMatrixSink, MmapCondensedSink, OutputFormat, SinkMeta, StreamTsvSink};
use crate::table::FeatureTable;
use crate::tree::Phylogeny;
use crate::unifrac::CpuFeatures;
use crate::util::prng::Xoshiro256;
use crate::util::Real;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// How a finished worker process is handled, keyed off its exit code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Disposition {
    /// Exit 0: load, verify and flush the shard's partial.
    Success,
    /// Transient by construction (I/O, runtime, corruption, panic, or
    /// death by signal): re-queue the shard with backoff.
    Retry,
    /// Deterministic (bad config, bad input, unsupported combination):
    /// retrying reproduces it — fail the fleet with a typed error.
    Fatal,
}

/// Classify a worker exit code (`None` = killed by a signal) into a
/// [`Disposition`]. The codes are the stable per-error-class codes of
/// [`Error::code`] shared with the C ABI — see `include/unifrac.h`.
pub fn classify_exit(code: Option<i32>) -> Disposition {
    match code {
        None => Disposition::Retry, // signal: OOM-kill, node loss, injected abort
        Some(0) => Disposition::Success,
        // Io(10), Xla(17) and Corrupt(22) are environmental;
        // Overloaded(23) and DeadlineExceeded(24) are transient load
        // conditions of the query service; 99 is the CLI's panic code.
        // All can succeed on a healthy retry.
        Some(10) | Some(17) | Some(22..=24) | Some(99) => Disposition::Retry,
        // Newick(11), Table(12), Config(13), Manifest(14), Shape(15),
        // NoArtifact(16), Invalid(18), Cli(19), Unsupported(20),
        // Merge(21): deterministic — the same argv fails the same way.
        Some(11..=16) | Some(18..=21) => Disposition::Fatal,
        // unknown codes (future versions, shells): assume transient
        Some(_) => Disposition::Retry,
    }
}

/// What the supervisor needs beyond the [`JobSpec`]: the worker fleet's
/// shape, the retry/backoff policy, the on-disk inputs workers reload,
/// and the (optional) fault-injection plan.
#[derive(Clone, Debug)]
pub struct FleetSpec {
    /// Feature-table path workers load (`.tsv` or `.bin`).
    pub table: PathBuf,
    /// Newick tree path workers load.
    pub tree: PathBuf,
    /// Where the final matrix lands (format per [`JobSpec::output_format`]).
    pub output: PathBuf,
    /// Concurrent worker processes (minimum 1).
    pub workers: usize,
    /// Stripes per shard; 0 sizes shards automatically to ~4 waves per
    /// worker. Slower workers receive proportionally smaller shards.
    pub shard_stripes: usize,
    /// Per-shard wall-clock limit; `Duration::ZERO` disables timeouts.
    pub timeout: Duration,
    /// Re-queue attempts per shard before the fleet fails.
    pub max_retries: usize,
    /// Base backoff delay in milliseconds (doubles per attempt).
    pub backoff_base_ms: u64,
    /// Backoff ceiling in milliseconds.
    pub backoff_cap_ms: u64,
    /// Seed for the backoff jitter stream.
    pub seed: u64,
    /// Directory for shard partials; `None` puts them next to `output`
    /// (in `<output>.shards/`).
    pub work_dir: Option<PathBuf>,
    /// Keep shard partials after a successful flush (debugging).
    pub keep_partials: bool,
    /// Worker executable; `None` re-invokes the current executable.
    pub worker_program: Option<PathBuf>,
    /// Deterministic fault-injection plan (tests, CI chaos smoke).
    pub fault: Option<FaultPlan>,
}

impl Default for FleetSpec {
    fn default() -> Self {
        Self {
            table: PathBuf::new(),
            tree: PathBuf::new(),
            output: PathBuf::from("dm.tsv"),
            workers: 4,
            shard_stripes: 0,
            timeout: Duration::ZERO,
            max_retries: 3,
            backoff_base_ms: 50,
            backoff_cap_ms: 2000,
            seed: 42,
            work_dir: None,
            keep_partials: false,
            worker_program: None,
            fault: None,
        }
    }
}

/// What a supervised run did — the operator-facing accounting every
/// fault either shows up in (retries, timeouts, rejected partials) or
/// provably did not affect (a clean report plus a bit-identical matrix).
#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    /// Stripes in the job's stripe space.
    pub stripes_total: usize,
    /// Stripes already flushed by a prior interrupted run (resume).
    pub stripes_resumed: usize,
    /// Stripes computed (and flushed) by this run.
    pub stripes_computed: usize,
    /// Shards handed to workers (including in-process degraded ones).
    pub shards_dispatched: usize,
    /// Worker exits classified retryable (non-zero exit or signal).
    pub shards_failed: usize,
    /// Shard re-queues (failures + timeouts + corrupt partials).
    pub retries: usize,
    /// Workers killed for exceeding [`FleetSpec::timeout`].
    pub timeouts: usize,
    /// Partials rejected at load (checksum mismatch / torn write) —
    /// deleted and recomputed, never merged.
    pub corrupt_rejected: usize,
    /// Partials accepted WITHOUT checksum verification (v1 files from
    /// an older worker binary).
    pub checksum_skipped: usize,
    /// Shards computed in-process because spawning failed (graceful
    /// degradation down to a local single worker).
    pub degraded_shards: usize,
    /// Worker processes spawned over the fleet's lifetime.
    pub workers_spawned: usize,
    /// True when a `halt@K` fault stopped the fleet early: the sink is
    /// left resumable and the matrix is NOT finalized.
    pub halted: bool,
    /// Where the matrix landed.
    pub output: PathBuf,
}

/// Precision-erased sink: the supervisor flushes whichever payload
/// width the workers produced without being generic itself.
enum AnySink {
    F32(Box<dyn DistMatrixSink<f32>>),
    F64(Box<dyn DistMatrixSink<f64>>),
}

impl AnySink {
    fn build(job: &JobSpec, meta: SinkMeta, path: &std::path::Path) -> Result<Self> {
        Ok(match job.precision {
            FpWidth::F32 => AnySink::F32(build_typed::<f32>(job.output_format, meta, path)?),
            FpWidth::F64 => AnySink::F64(build_typed::<f64>(job.output_format, meta, path)?),
        })
    }

    fn missing_ranges(&self) -> Vec<(usize, usize)> {
        match self {
            AnySink::F32(s) => s.missing_ranges(),
            AnySink::F64(s) => s.missing_ranges(),
        }
    }

    fn put_partial(&mut self, p: &PartialResult) -> Result<()> {
        match (self, p.data()) {
            (AnySink::F32(s), PartialData::F32(b)) => s.put_block(b),
            (AnySink::F64(s), PartialData::F64(b)) => s.put_block(b),
            (AnySink::F32(_), PartialData::F64(_)) => Err(Error::invalid(
                "worker produced an f64 partial for an f32 fleet run",
            )),
            (AnySink::F64(_), PartialData::F32(_)) => Err(Error::invalid(
                "worker produced an f32 partial for an f64 fleet run",
            )),
        }
    }

    fn finish(&mut self) -> Result<()> {
        match self {
            AnySink::F32(s) => s.finish(),
            AnySink::F64(s) => s.finish(),
        }
    }

    fn abandon(&mut self) -> Result<()> {
        match self {
            AnySink::F32(s) => s.abandon(),
            AnySink::F64(s) => s.abandon(),
        }
    }
}

fn build_typed<R: Real>(
    format: OutputFormat,
    meta: SinkMeta,
    path: &std::path::Path,
) -> Result<Box<dyn DistMatrixSink<R>>> {
    Ok(match format {
        // tsv resumes from its spool, mmap from its coverage bitmap;
        // bin is write-once (fresh file, full recompute)
        OutputFormat::Tsv => Box::new(StreamTsvSink::create(path, meta)?),
        OutputFormat::Bin => Box::new(MmapCondensedSink::create_buffered(path, meta)?),
        OutputFormat::Mmap => Box::new(MmapCondensedSink::create_or_resume(path, meta)?),
    })
}

/// A shard waiting to run (fresh, or re-queued after a failure).
#[derive(Clone, Copy, Debug)]
struct Pending {
    start: usize,
    count: usize,
    /// Completed failed attempts so far (0 = never dispatched).
    attempt: usize,
    ready_at: Instant,
}

/// A shard currently running in a worker process.
struct Running {
    child: Child,
    start: usize,
    count: usize,
    attempt: usize,
    out: PathBuf,
    t0: Instant,
}

/// Exponential backoff with jitter: `min(cap, base * 2^attempt)` plus a
/// uniform jitter in `[0, base)` milliseconds.
fn backoff_ms(base: u64, cap: u64, attempt: usize, prng: &mut Xoshiro256) -> u64 {
    let exp = base.saturating_mul(1u64 << attempt.min(16)).min(cap.max(base));
    exp + prng.below(base.max(1) as usize) as u64
}

/// Shard size for a slot given the measured per-slot rates
/// (stripes/sec; 0 = unmeasured): proportional to the slot's speed
/// relative to the fleet mean, clamped to `[1, 4 * base]`.
fn shard_size_for(base: usize, rates: &[f64], slot: usize) -> usize {
    let known: Vec<f64> = rates.iter().copied().filter(|r| *r > 0.0).collect();
    if known.is_empty() || rates[slot] <= 0.0 {
        return base.max(1);
    }
    let mean = known.iter().sum::<f64>() / known.len() as f64;
    if mean <= 0.0 {
        return base.max(1);
    }
    let scaled = (base as f64 * rates[slot] / mean).round() as usize;
    scaled.clamp(1, base.saturating_mul(4).max(1))
}

fn kill_all(running: &mut [Option<Running>]) {
    for slot in running.iter_mut() {
        if let Some(mut r) = slot.take() {
            let _ = r.child.kill();
            let _ = r.child.wait();
        }
    }
}

/// Verify a loaded partial against the shard the supervisor dispatched.
/// Any mismatch is deterministic (wrong binary, wrong inputs) — fatal.
fn validate_partial(
    p: &PartialResult,
    table: &FeatureTable,
    job: &JobSpec,
    padded: usize,
    start: usize,
    count: usize,
) -> Result<()> {
    let m = p.meta();
    if m.stripe_start != start || m.stripe_count != count {
        return Err(Error::invalid(format!(
            "worker partial covers stripes {}+{}, supervisor dispatched {start}+{count}",
            m.stripe_start, m.stripe_count
        )));
    }
    if m.padded_n != padded || m.n_samples != table.n_samples() {
        return Err(Error::invalid(format!(
            "worker partial geometry ({} samples padded {}) disagrees with the fleet \
             ({} samples padded {padded}) — mismatched inputs or binary",
            m.n_samples,
            m.padded_n,
            table.n_samples()
        )));
    }
    if m.metric != job.metric || m.fp != job.precision {
        return Err(Error::invalid(format!(
            "worker partial computed {}/{}, fleet wants {}/{}",
            m.metric,
            m.fp.name(),
            job.metric,
            job.precision.name()
        )));
    }
    if m.sample_ids.as_slice() != table.sample_ids() {
        return Err(Error::invalid(
            "worker partial sample ids disagree with the fleet's table",
        ));
    }
    Ok(())
}

/// Run `job` over `(tree, table)` as a supervised multi-process fleet
/// per `fleet`, writing the matrix to `fleet.output`.
///
/// The caller loads the problem once (the same files named by
/// `fleet.table` / `fleet.tree` that workers reload); the supervisor
/// resolves the geometry, opens a resumable sink, dispatches the
/// missing stripe ranges as shards, and survives worker failure per the
/// module docs. Returns the [`FleetReport`] accounting; the matrix is
/// finalized unless a `halt@K` fault stopped the fleet early.
pub fn supervise(
    tree: &Phylogeny,
    table: &FeatureTable,
    job: &JobSpec,
    fleet: &FleetSpec,
) -> Result<FleetReport> {
    if job.stripe_range.is_some() {
        return Err(Error::invalid(
            "supervise runs the whole stripe space; drop the JobSpec stripe_range",
        ));
    }
    // the supervisor never fires worker-side faults itself — they reach
    // workers via argv only (single-fire, owned by the dispatch loop)
    let mut local = job.clone();
    local.fault = None;
    let jobh = UniFracJob::with_spec(tree, table, local);
    let (engine, padded, s_total) = jobh.geometry()?;

    let meta = SinkMeta {
        n_samples: table.n_samples(),
        padded_n: padded,
        metric: job.metric,
        fp_bytes: job.precision.bytes(),
        sample_ids: table.sample_ids().to_vec(),
    };
    let mut sink = AnySink::build(job, meta, &fleet.output)?;
    let mut remaining: VecDeque<(usize, usize)> = sink.missing_ranges().into();
    let owed: usize = remaining.iter().map(|r| r.1).sum();

    let mut report = FleetReport {
        stripes_total: s_total,
        stripes_resumed: s_total - owed,
        output: fleet.output.clone(),
        ..Default::default()
    };

    let workers_n = fleet.workers.max(1);
    let base_shard = if fleet.shard_stripes > 0 {
        fleet.shard_stripes
    } else {
        (owed / (workers_n * 4)).max(1)
    };
    let program = match &fleet.worker_program {
        Some(p) => p.clone(),
        None => std::env::current_exe()?,
    };
    let work_dir = fleet
        .work_dir
        .clone()
        .unwrap_or_else(|| fleet.output.with_extension("shards"));
    std::fs::create_dir_all(&work_dir)?;

    let mut fault = fleet.fault.clone().unwrap_or_else(|| FaultPlan::empty(fleet.seed));
    let halt_after = fault.halt_after();

    let mut running: Vec<Option<Running>> = (0..workers_n).map(|_| None).collect();
    let mut retries: Vec<Pending> = Vec::new();
    let mut rates: Vec<f64> = vec![0.0; workers_n];
    let mut prng = Xoshiro256::new(fleet.seed ^ 0xF1EE_7F1E);
    let mut flushed_shards = 0usize;

    // one closure per failure path: re-queue with backoff, or fail the
    // fleet once the shard's retry budget is spent
    let requeue = |p: Pending,
                   why: &str,
                   retries: &mut Vec<Pending>,
                   report: &mut FleetReport,
                   prng: &mut Xoshiro256|
     -> Result<()> {
        if p.attempt >= fleet.max_retries {
            return Err(Error::invalid(format!(
                "shard {}+{} failed {} attempts (last: {why}); giving up",
                p.start,
                p.count,
                p.attempt + 1
            )));
        }
        let delay = backoff_ms(fleet.backoff_base_ms, fleet.backoff_cap_ms, p.attempt, prng);
        report.retries += 1;
        retries.push(Pending {
            attempt: p.attempt + 1,
            ready_at: Instant::now() + Duration::from_millis(delay),
            ..p
        });
        Ok(())
    };

    'fleet: loop {
        let now = Instant::now();

        // ---- dispatch: fill every free slot ----
        for slot in 0..workers_n {
            if running[slot].is_some() {
                continue;
            }
            // ready re-queued shards first (they block completion)
            let next = if let Some(i) = retries.iter().position(|p| p.ready_at <= now) {
                Some(retries.swap_remove(i))
            } else {
                remaining.pop_front().map(|(start, count)| {
                    let take = shard_size_for(base_shard, &rates, slot).min(count);
                    if take < count {
                        remaining.push_front((start + take, count - take));
                    }
                    Pending { start, count: take, attempt: 0, ready_at: now }
                })
            };
            let Some(p) = next else { continue };
            report.shards_dispatched += 1;
            // faults fire on a shard's FIRST dispatch only
            let fault_arg =
                if p.attempt == 0 { fault.take_for_range(p.start, p.count) } else { None };
            let out = work_dir.join(format!("shard_{}_{}.ufpr", p.start, p.count));
            let _ = std::fs::remove_file(&out);
            let mut cmd = Command::new(&program);
            cmd.arg("worker")
                .arg("--table")
                .arg(&fleet.table)
                .arg("--tree")
                .arg(&fleet.tree)
                .arg("--start")
                .arg(p.start.to_string())
                .arg("--count")
                .arg(p.count.to_string())
                .arg("--out")
                .arg(&out)
                .arg("--metric")
                .arg(job.metric.name())
                .arg("--alpha")
                .arg(job.metric.alpha().to_string())
                .arg("--dtype")
                .arg(job.precision.name())
                .arg("--engine")
                .arg(engine.name())
                .arg("--block-k")
                .arg(job.block_k.to_string())
                .arg("--sparse-threshold")
                .arg(job.sparse_threshold.to_string())
                .arg("--threads")
                .arg(job.threads.to_string())
                .arg("--batch")
                .arg(job.batch_capacity.to_string())
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                // fault plans reach workers only through --fault on
                // their FIRST dispatch: a UNIFRAC_FAULT set on the
                // supervisor is the *fleet's* plan, and inheriting it
                // would re-fire every fault on every retry
                .env_remove("UNIFRAC_FAULT");
            if job.cpu_features != CpuFeatures::Auto {
                cmd.arg("--cpu-features").arg(job.cpu_features.name());
            }
            if let Some(spec) = &fault_arg {
                // the corruption PRNG is seeded worker-side from the
                // config seed — pin it so flips reproduce per fleet seed
                cmd.arg("--seed").arg(fleet.seed.to_string());
                cmd.arg("--fault").arg(spec);
            }
            match cmd.spawn() {
                Ok(child) => {
                    report.workers_spawned += 1;
                    running[slot] = Some(Running {
                        child,
                        start: p.start,
                        count: p.count,
                        attempt: p.attempt,
                        out,
                        t0: now,
                    });
                }
                Err(_) => {
                    // graceful degradation: no subprocess available —
                    // compute the shard in-process (single local worker)
                    let part = match jobh.run_partial_range(p.start, p.count) {
                        Ok(part) => part,
                        Err(e) => {
                            kill_all(&mut running);
                            let _ = sink.abandon();
                            return Err(e);
                        }
                    };
                    if let Err(e) = sink.put_partial(&part) {
                        kill_all(&mut running);
                        let _ = sink.abandon();
                        return Err(e);
                    }
                    report.degraded_shards += 1;
                    report.stripes_computed += p.count;
                    flushed_shards += 1;
                    if halt_after.map_or(false, |k| flushed_shards >= k) {
                        report.halted = true;
                        break 'fleet;
                    }
                }
            }
        }

        // ---- completion check ----
        if remaining.is_empty() && retries.is_empty() && running.iter().all(Option::is_none) {
            break 'fleet;
        }

        // ---- poll the fleet ----
        for slot in 0..workers_n {
            enum Event {
                Exited(Option<i32>),
                TimedOut,
            }
            let event = match &mut running[slot] {
                None => continue,
                Some(r) => match r.child.try_wait() {
                    Ok(Some(status)) => Event::Exited(status.code()),
                    Ok(None) => {
                        if !fleet.timeout.is_zero() && r.t0.elapsed() > fleet.timeout {
                            Event::TimedOut
                        } else {
                            continue;
                        }
                    }
                    // losing track of a child is indistinguishable from
                    // losing the child: kill and re-queue
                    Err(_) => Event::TimedOut,
                },
            };
            let mut r = running[slot].take().expect("polled slot is occupied");
            let p = Pending { start: r.start, count: r.count, attempt: r.attempt, ready_at: now };
            match event {
                Event::TimedOut => {
                    let _ = r.child.kill();
                    let _ = r.child.wait();
                    let _ = std::fs::remove_file(&r.out);
                    report.timeouts += 1;
                    if let Err(e) = requeue(p, "timeout", &mut retries, &mut report, &mut prng) {
                        kill_all(&mut running);
                        let _ = sink.abandon();
                        return Err(e);
                    }
                }
                Event::Exited(code) => match classify_exit(code) {
                    Disposition::Fatal => {
                        kill_all(&mut running);
                        let _ = sink.abandon();
                        return Err(Error::invalid(format!(
                            "worker for shard {}+{} failed fatally (exit code {code:?}); \
                             this failure is deterministic — not retrying",
                            r.start, r.count
                        )));
                    }
                    Disposition::Retry => {
                        let _ = std::fs::remove_file(&r.out);
                        report.shards_failed += 1;
                        let why = format!("exit {code:?}");
                        if let Err(e) = requeue(p, &why, &mut retries, &mut report, &mut prng) {
                            kill_all(&mut running);
                            let _ = sink.abandon();
                            return Err(e);
                        }
                    }
                    Disposition::Success => {
                        match PartialResult::load_checked(&r.out) {
                            Ok((part, check)) => {
                                if let Err(e) = validate_partial(
                                    &part, table, job, padded, r.start, r.count,
                                ) {
                                    kill_all(&mut running);
                                    let _ = sink.abandon();
                                    return Err(e);
                                }
                                if let Err(e) = sink.put_partial(&part) {
                                    kill_all(&mut running);
                                    let _ = sink.abandon();
                                    return Err(e);
                                }
                                if !check.checksummed {
                                    report.checksum_skipped += 1;
                                }
                                if !fleet.keep_partials {
                                    let _ = std::fs::remove_file(&r.out);
                                }
                                report.stripes_computed += r.count;
                                flushed_shards += 1;
                                // rate: EWMA of stripes/sec for this slot
                                let secs = r.t0.elapsed().as_secs_f64().max(1e-6);
                                let rate = r.count as f64 / secs;
                                rates[slot] = if rates[slot] > 0.0 {
                                    0.5 * rates[slot] + 0.5 * rate
                                } else {
                                    rate
                                };
                                if halt_after.map_or(false, |k| flushed_shards >= k) {
                                    report.halted = true;
                                    break 'fleet;
                                }
                            }
                            // a partial that exists but fails its CRC or
                            // its parse is a torn/corrupt artifact:
                            // delete, count, recompute — NEVER merged
                            Err(Error::Corrupt(_)) | Err(Error::Invalid(_)) | Err(Error::Io(_)) => {
                                let _ = std::fs::remove_file(&r.out);
                                report.corrupt_rejected += 1;
                                if let Err(e) = requeue(
                                    p,
                                    "corrupt partial",
                                    &mut retries,
                                    &mut report,
                                    &mut prng,
                                ) {
                                    kill_all(&mut running);
                                    let _ = sink.abandon();
                                    return Err(e);
                                }
                            }
                            Err(e) => {
                                kill_all(&mut running);
                                let _ = sink.abandon();
                                return Err(e);
                            }
                        }
                    }
                },
            }
        }

        std::thread::sleep(Duration::from_millis(3));
    }

    kill_all(&mut running);
    if report.halted {
        // leave the sink resumable: a re-run picks up from the coverage
        // bitmap / spool and computes only the missing ranges
        return Ok(report);
    }
    sink.finish()?;
    if !fleet.keep_partials {
        let _ = std::fs::remove_dir(&work_dir); // only if empty
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite 2: every error class the worker can exit with must map
    /// to a deliberate disposition. The loop walks the full assigned
    /// code range (10..=22, per `Error::code`) and the sentinel below
    /// pins the range end — assigning a new error code moves the
    /// sentinel and forces a classification decision here.
    #[test]
    fn classification_covers_every_error_code() {
        for code in 10..=24 {
            let name = Error::code_name(code);
            assert_ne!(name, "unknown", "code {code} must be an assigned error class");
            let d = classify_exit(Some(code));
            assert_ne!(d, Disposition::Success, "error code {code} classified as success");
            let expect_retry =
                matches!(name, "io" | "xla" | "corrupt" | "overloaded" | "deadline");
            assert_eq!(
                d,
                if expect_retry { Disposition::Retry } else { Disposition::Fatal },
                "unexpected disposition for {name} (code {code})"
            );
        }
        // sentinel: 25 is unassigned today; when a variant claims it,
        // extend the loop above AND pick its disposition deliberately
        assert_eq!(Error::code_name(25), "unknown");
        // the non-variant codes
        assert_eq!(classify_exit(Some(0)), Disposition::Success);
        assert_eq!(Error::code_name(99), "panic");
        assert_eq!(classify_exit(Some(99)), Disposition::Retry, "panic code retries");
        assert_eq!(classify_exit(None), Disposition::Retry, "signal death retries");
        assert_eq!(classify_exit(Some(42)), Disposition::Retry, "unknown codes retry");
    }

    #[test]
    fn backoff_grows_caps_and_jitters_within_base() {
        let mut prng = Xoshiro256::new(7);
        let mut prev_floor = 0u64;
        for attempt in 0..10 {
            let d = backoff_ms(50, 2000, attempt, &mut prng);
            let floor = (50u64 << attempt.min(16)).min(2000);
            assert!(d >= floor, "attempt {attempt}: {d} < floor {floor}");
            assert!(d < floor + 50, "attempt {attempt}: jitter exceeds base");
            assert!(floor >= prev_floor, "backoff floor must be monotone");
            prev_floor = floor;
        }
        // overflow safety at absurd attempt counts
        assert!(backoff_ms(50, 2000, 1000, &mut prng) < 2050);
    }

    #[test]
    fn slower_slots_get_smaller_shards() {
        // no measurements yet: everyone gets the base size
        assert_eq!(shard_size_for(8, &[0.0, 0.0], 0), 8);
        // slot 1 runs at half the fleet mean -> roughly half the shard
        let rates = [30.0, 10.0];
        let fast = shard_size_for(8, &rates, 0);
        let slow = shard_size_for(8, &rates, 1);
        assert!(fast > slow, "fast {fast} <= slow {slow}");
        assert!(slow >= 1);
        // clamp: a hot slot never exceeds 4x base
        assert!(shard_size_for(8, &[1000.0, 1.0], 0) <= 32);
    }
}
