//! ISSUE 5 property suite: every output sink is bit-identical to the
//! in-memory path, across engines × metrics × precisions, including
//! multi-partition merges into a sink and kill-and-resume round trips —
//! and the out-of-core sweep keeps the sink's resident set bounded by
//! scratch (flush accounting), never by the full matrix.

use std::path::PathBuf;
use unifrac::matrix::{
    total_stripes, CondensedFile, DistMatrixSink, MmapCondensedSink, OutputFormat, SinkMeta,
};
use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::api::PartialData;
use unifrac::unifrac::EngineKind;
use unifrac::{FpWidth, Metric, UniFracJob};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("unifrac_sink_equivalence").join(name);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn problem() -> (Phylogeny, FeatureTable) {
    SynthSpec { n_samples: 18, n_features: 96, density: 0.1, ..Default::default() }.generate()
}

/// The tentpole equality: for every engine × supported metric × fp
/// width, the three sinks produce the same bytes as the in-memory run.
#[test]
fn all_sinks_bit_identical_to_in_memory_across_engines() {
    let (tree, table) = problem();
    let dir = tmpdir("matrixwide");
    for metric in Metric::all(0.5) {
        for engine in EngineKind::all() {
            if !engine.supports(metric) {
                continue;
            }
            for fp in [FpWidth::F64, FpWidth::F32] {
                let tag = format!("{}_{}_{}", metric.name(), engine.name(), fp.name());
                let job = |fmt: OutputFormat| {
                    UniFracJob::new(&tree, &table)
                        .metric(metric)
                        .engine(engine)
                        .precision(fp)
                        .output_format(fmt)
                };
                let dm = job(OutputFormat::Tsv).run().unwrap();
                let want = dir.join(format!("{tag}.want.tsv"));
                dm.write_tsv(&want).unwrap();
                let want_bytes = std::fs::read(&want).unwrap();
                for fmt in OutputFormat::ALL {
                    let out = dir.join(format!("{tag}.{fmt}"));
                    let rep = job(fmt).run_to_path(&out).unwrap();
                    assert_eq!(rep.stripes_computed, rep.stripes_total, "{tag} {fmt}");
                    let got_bytes = match fmt {
                        OutputFormat::Tsv => std::fs::read(&out).unwrap(),
                        OutputFormat::Bin | OutputFormat::Mmap => {
                            let f = CondensedFile::open(&out).unwrap();
                            assert_eq!(f.to_matrix().max_abs_diff(&dm), 0.0, "{tag} {fmt}");
                            assert_eq!(f.fp_bytes(), fp.bytes(), "{tag} {fmt}");
                            let back = dir.join(format!("{tag}.{fmt}.tsv"));
                            f.write_tsv(&back).unwrap();
                            std::fs::read(&back).unwrap()
                        }
                    };
                    assert_eq!(got_bytes, want_bytes, "{tag} {fmt} not byte-identical");
                }
            }
        }
    }
}

/// `bin` and `mmap` are two write backends over the same format: their
/// files must be byte-identical to each other, too.
#[test]
fn bin_and_mmap_files_are_byte_identical() {
    let (tree, table) = problem();
    let dir = tmpdir("backends");
    let pb = dir.join("dm.bin");
    let pm = dir.join("dm.mmap");
    UniFracJob::new(&tree, &table)
        .output_format(OutputFormat::Bin)
        .run_to_path(&pb)
        .unwrap();
    UniFracJob::new(&tree, &table)
        .output_format(OutputFormat::Mmap)
        .run_to_path(&pm)
        .unwrap();
    assert_eq!(std::fs::read(&pb).unwrap(), std::fs::read(&pm).unwrap());
}

/// Multi-partition merge through a sink: stripe partials computed
/// independently (the distributed lifecycle) flush into one mmap sink
/// and reproduce the one-shot matrix exactly.
#[test]
fn partials_flush_into_mmap_sink_bit_identically() {
    let (tree, table) = problem();
    let dir = tmpdir("partials");
    let job = UniFracJob::new(&tree, &table);
    let want = dir.join("want.tsv");
    job.run().unwrap().write_tsv(&want).unwrap();

    let parts: Vec<_> =
        (0..3).map(|i| job.run_partial_index(i, 3).unwrap()).collect();
    let meta = parts[0].meta();
    let sink_meta = SinkMeta {
        n_samples: meta.n_samples,
        padded_n: meta.padded_n,
        metric: meta.metric,
        fp_bytes: meta.fp.bytes(),
        sample_ids: meta.sample_ids.clone(),
    };
    let path = dir.join("merged.ufdm");
    let mut sink = MmapCondensedSink::create(&path, sink_meta).unwrap();
    for p in &parts {
        match p.data() {
            PartialData::F64(b) => DistMatrixSink::<f64>::put_block(&mut sink, b).unwrap(),
            PartialData::F32(_) => panic!("default precision is f64"),
        }
    }
    DistMatrixSink::<f64>::finish(&mut sink).unwrap();
    drop(sink);
    let back = dir.join("merged.tsv");
    CondensedFile::open(&path).unwrap().write_tsv(&back).unwrap();
    assert_eq!(std::fs::read(&want).unwrap(), std::fs::read(&back).unwrap());
}

/// Kill-and-resume round trip at the job level: a run killed after one
/// partial's flush is resumed by simply re-running `run_to_path` at the
/// same path — only the missing stripes are recomputed, and the final
/// bytes match an uninterrupted run.
#[test]
fn killed_run_resumes_and_matches() {
    let (tree, table) = problem();
    let dir = tmpdir("resume");
    let job = UniFracJob::new(&tree, &table).output_format(OutputFormat::Mmap);
    let want = dir.join("want.tsv");
    job.run().unwrap().write_tsv(&want).unwrap();

    // simulate the kill: flush only the first of three partials, then
    // drop the sink without finish()
    let p0 = job.run_partial_index(0, 3).unwrap();
    let meta = p0.meta();
    let first = meta.stripe_count;
    let total = total_stripes(meta.padded_n);
    let path = dir.join("dm.ufdm");
    {
        let sink_meta = SinkMeta {
            n_samples: meta.n_samples,
            padded_n: meta.padded_n,
            metric: meta.metric,
            fp_bytes: meta.fp.bytes(),
            sample_ids: meta.sample_ids.clone(),
        };
        let mut sink = MmapCondensedSink::create(&path, sink_meta).unwrap();
        match p0.data() {
            PartialData::F64(b) => DistMatrixSink::<f64>::put_block(&mut sink, b).unwrap(),
            PartialData::F32(_) => panic!("default precision is f64"),
        }
    }

    let rep = job.run_to_path(&path).unwrap();
    assert_eq!(rep.stripes_resumed, first, "prior flush must be skipped");
    assert_eq!(rep.stripes_computed, total - first);
    let back = dir.join("resumed.tsv");
    CondensedFile::open(&path).unwrap().write_tsv(&back).unwrap();
    assert_eq!(std::fs::read(&want).unwrap(), std::fs::read(&back).unwrap());

    // a second run over the complete file computes nothing
    let rep = job.run_to_path(&path).unwrap();
    assert_eq!(rep.stripes_resumed, total);
    assert_eq!(rep.stripes_computed, 0);
}

/// The ISSUE-5 acceptance criterion: an out-of-core `mmap` run produces
/// bytes identical to the in-memory TSV path while the sink's resident
/// high-water mark stays at per-stripe scratch — orders of magnitude
/// below the full condensed payload — proven by flush accounting, not
/// by allocating the matrix.
#[test]
fn budget_sweep_bounds_resident_set_and_matches_in_memory() {
    let (tree, table) =
        SynthSpec { n_samples: 400, n_features: 600, density: 0.02, ..Default::default() }
            .generate();
    let dir = tmpdir("budget");
    let job = UniFracJob::new(&tree, &table).metric(Metric::Unweighted);
    let want = dir.join("want.tsv");
    job.run().unwrap().write_tsv(&want).unwrap();

    let out = dir.join("dm.ufdm");
    let rep = UniFracJob::new(&tree, &table)
        .metric(Metric::Unweighted)
        .output_format(OutputFormat::Mmap)
        .pool_depth(2)
        .batch_capacity(8)
        .max_resident_mb(1)
        .run_to_path(&out)
        .unwrap();
    assert!(rep.passes >= 2, "1 MiB budget must force a multi-pass sweep, got {rep:?}");
    assert_eq!(rep.stripes_computed, rep.stripes_total);

    let n = table.n_samples() as u64;
    let payload_bytes = n * (n - 1) / 2 * 8;
    assert_eq!(rep.stats.payload_bytes_written, payload_bytes, "every pair written once");
    // bounded by scratch: one stripe's entry list + coverage map, not O(N²)
    assert!(
        rep.stats.peak_resident_bytes < 64 * 1024,
        "sink resident {} must stay at per-stripe scratch",
        rep.stats.peak_resident_bytes
    );
    assert!(
        rep.stats.peak_resident_bytes * 4 < payload_bytes,
        "sink resident {} must stay far below the {} payload",
        rep.stats.peak_resident_bytes,
        payload_bytes
    );

    let back = dir.join("back.tsv");
    CondensedFile::open(&out).unwrap().write_tsv(&back).unwrap();
    assert_eq!(
        std::fs::read(&want).unwrap(),
        std::fs::read(&back).unwrap(),
        "out-of-core sweep must be byte-identical to the in-memory TSV"
    );
}

/// The coordinator path flushes per chip into the sink.
#[test]
fn multi_chip_run_streams_to_sink() {
    let (tree, table) = problem();
    let dir = tmpdir("chips");
    let want = dir.join("want.tsv");
    UniFracJob::new(&tree, &table).run().unwrap().write_tsv(&want).unwrap();
    let out = dir.join("dm.bin");
    let rep = UniFracJob::new(&tree, &table)
        .chips(3)
        .output_format(OutputFormat::Bin)
        .run_to_path(&out)
        .unwrap();
    assert_eq!(rep.stripes_computed, rep.stripes_total);
    let back = dir.join("back.tsv");
    CondensedFile::open(&out).unwrap().write_tsv(&back).unwrap();
    assert_eq!(std::fs::read(&want).unwrap(), std::fs::read(&back).unwrap());
}

/// Guard rails: misconfigured out-of-core requests fail with typed
/// errors instead of computing something surprising.
#[test]
fn out_of_core_guard_rails() {
    let (tree, table) = problem();
    let dir = tmpdir("guards");
    // budget sweeps are single-node CPU only
    let err = UniFracJob::new(&tree, &table)
        .chips(2)
        .max_resident_mb(64)
        .run_to_path(dir.join("x.bin"))
        .unwrap_err();
    assert!(matches!(err, unifrac::Error::Unsupported(_)), "got {err:?}");
    // a set stripe_range must not silently stream a full matrix
    let err = UniFracJob::new(&tree, &table)
        .stripe_range(0, 1)
        .run_to_path(dir.join("y.bin"))
        .unwrap_err();
    assert!(err.to_string().contains("run_partial"), "{err}");
    // a budget too small for one stripe is a config error
    let err = UniFracJob::new(&tree, &table)
        .max_resident_mb(0)
        .run_to_path(dir.join("z.bin"))
        .unwrap_err();
    assert!(matches!(err, unifrac::Error::Config(_)), "got {err:?}");
    // an incomplete file is rejected by the reader with a resume hint
    let p = dir.join("incomplete.ufdm");
    {
        let meta = SinkMeta {
            n_samples: table.n_samples(),
            padded_n: 20,
            metric: Metric::WeightedNormalized,
            fp_bytes: 8,
            sample_ids: table.sample_ids().to_vec(),
        };
        MmapCondensedSink::create(&p, meta).unwrap();
    }
    let err = CondensedFile::open(&p).unwrap_err();
    assert!(err.to_string().contains("resume"), "{err}");
}
