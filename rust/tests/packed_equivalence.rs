//! Packed-vs-scalar equivalence suite (ISSUE 2 satellite): the
//! bit-packed unweighted kernel must agree with the scalar engines to
//! <1e-12 on random presence tables, across the remainder-mask edge
//! cases (embedding counts around the 64-bit word boundary) and across
//! multi-batch accumulation.

use unifrac::embed::{collect_batches, EmbBatch, EmbeddingKind, PackedStream};
use unifrac::matrix::{total_stripes, StripeBlock};
use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::unifrac::{
    compute_unifrac, compute_unifrac_naive, make_engine, ComputeOptions, EngineKind, Metric,
    PackedBatch,
};
use unifrac::util::Xoshiro256;

fn problem(n: usize, features: usize, seed: u64) -> (Phylogeny, FeatureTable) {
    SynthSpec { n_samples: n, n_features: features, density: 0.1, seed, ..Default::default() }
        .generate()
}

/// Random presence batch with the canonical `[mass | mass]` duplication.
fn presence_batch(n: usize, rows: usize, seed: u64) -> EmbBatch<f64> {
    let mut rng = Xoshiro256::new(seed);
    let mut b = EmbBatch::<f64>::new(n, rows);
    let mut mass = vec![0.0; n];
    for e in 0..rows {
        for m in mass.iter_mut() {
            *m = f64::from(rng.f64() < 0.35);
        }
        let len = rng.f64().max(1e-3);
        for (k, &m) in mass.iter().enumerate() {
            b.emb[e * 2 * n + k] = m;
            b.emb[e * 2 * n + n + k] = m;
        }
        b.lengths[e] = len;
        b.filled = e + 1;
    }
    b
}

/// Property: `Packed` matches `Tiled` and `Original` on random presence
/// tables for the word-boundary embedding counts 1, 63, 64, 65, 200.
#[test]
fn packed_matches_scalar_at_word_boundaries() {
    for &rows in &[1usize, 63, 64, 65, 200] {
        for seed in 0..3u64 {
            let n = 20;
            let batch = presence_batch(n, rows, 9000 + rows as u64 * 10 + seed);
            let mut packed = PackedBatch::<f64>::new(n, rows);
            packed.pack_from(&batch);
            packed.build_luts();
            let mut got = StripeBlock::<f64>::new(n, 0, total_stripes(n));
            packed.apply_unweighted(&mut got);
            for kind in [EngineKind::Tiled, EngineKind::Original] {
                let eng = make_engine::<f64>(kind, 8);
                let mut want = StripeBlock::<f64>::new(n, 0, total_stripes(n));
                eng.apply(Metric::Unweighted, &batch, &mut want);
                let diff = want.max_abs_diff(&got);
                assert!(diff < 1e-12, "rows={rows} seed={seed} vs {kind:?}: diff {diff}");
            }
        }
    }
}

/// Property: folding batches one by one equals folding their
/// concatenation (accumulation across multiple batches).
#[test]
fn packed_accumulates_across_batches() {
    let n = 16;
    let parts = [
        presence_batch(n, 40, 1),
        presence_batch(n, 63, 2),
        presence_batch(n, 65, 3),
    ];
    let mut split = StripeBlock::<f64>::new(n, 0, total_stripes(n));
    for part in &parts {
        let mut p = PackedBatch::<f64>::new(n, part.filled);
        p.pack_from(part);
        p.build_luts();
        p.apply_unweighted(&mut split);
    }
    // concatenation
    let total: usize = parts.iter().map(|p| p.filled).sum();
    let mut concat = EmbBatch::<f64>::new(n, total);
    let mut e = 0;
    for part in &parts {
        for (row, len) in part.rows() {
            concat.emb[e * 2 * n..(e + 1) * 2 * n].copy_from_slice(row);
            concat.lengths[e] = len;
            e += 1;
        }
    }
    concat.filled = total;
    let mut p = PackedBatch::<f64>::new(n, total);
    p.pack_from(&concat);
    p.build_luts();
    let mut whole = StripeBlock::<f64>::new(n, 0, total_stripes(n));
    p.apply_unweighted(&mut whole);
    assert!(split.max_abs_diff(&whole) < 1e-12);
}

/// End-to-end: the auto-selected packed engine matches the explicit
/// scalar engines and the naive oracle on random problems, across batch
/// capacities that hit the remainder-mask path.
#[test]
fn packed_end_to_end_matches_scalar_and_oracle() {
    for (n, features, seed) in [(9usize, 64usize, 5u64), (21, 128, 6), (32, 200, 7)] {
        let (tree, table) = problem(n, features, seed);
        let oracle = compute_unifrac_naive(&tree, &table, Metric::Unweighted).unwrap();
        for batch_capacity in [1usize, 63, 64, 65, 200] {
            let opts = ComputeOptions {
                metric: Metric::Unweighted,
                batch_capacity,
                ..Default::default()
            };
            // auto-selection picks packed for unweighted
            assert_eq!(opts.resolved_engine(), EngineKind::Packed);
            let packed = compute_unifrac::<f64>(&tree, &table, &opts).unwrap();
            let diff = packed.max_abs_diff(&oracle);
            assert!(diff < 1e-12, "n={n} cap={batch_capacity}: oracle diff {diff}");
            let tiled = compute_unifrac::<f64>(
                &tree,
                &table,
                &ComputeOptions { engine: Some(EngineKind::Tiled), ..opts.clone() },
            )
            .unwrap();
            let diff = packed.max_abs_diff(&tiled);
            assert!(diff < 1e-12, "n={n} cap={batch_capacity}: tiled diff {diff}");
        }
    }
}

/// The packed producer (`PackedStream`) agrees with packing the scalar
/// presence stream after the fact — bit-for-bit the same fold result.
#[test]
fn packed_stream_equals_repacked_scalar_stream() {
    let (tree, table) = problem(14, 96, 11);
    for capacity in [1usize, 63, 64, 65, 200] {
        let scalar =
            collect_batches::<f64>(&tree, &table, EmbeddingKind::Presence, 14, capacity)
                .unwrap();
        let mut from_scalar = StripeBlock::<f64>::new(14, 0, total_stripes(14));
        for b in &scalar {
            let mut p = PackedBatch::<f64>::new(14, capacity);
            p.pack_from(b);
            p.build_luts();
            p.apply_unweighted(&mut from_scalar);
        }
        let mut stream = PackedStream::new(&tree, &table).unwrap();
        let mut direct = StripeBlock::<f64>::new(14, 0, total_stripes(14));
        let mut packed = PackedBatch::<f64>::new(14, capacity);
        loop {
            packed.reset();
            if stream.fill(&mut packed) == 0 {
                break;
            }
            packed.apply_unweighted(&mut direct);
        }
        assert!(
            from_scalar.max_abs_diff(&direct) < 1e-12,
            "capacity={capacity}: stream/pack divergence"
        );
        assert_eq!(stream.produced(), tree.n_nodes() - 1);
    }
}

/// Multi-threaded packed runs agree with single-threaded ones.
#[test]
fn packed_multithreaded_matches_single() {
    let (tree, table) = problem(40, 256, 13);
    let base = ComputeOptions {
        metric: Metric::Unweighted,
        batch_capacity: 8,
        ..Default::default()
    };
    let single = compute_unifrac::<f64>(&tree, &table, &base).unwrap();
    for threads in [2usize, 3, 8] {
        let multi = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { threads, ..base.clone() },
        )
        .unwrap();
        assert!(single.max_abs_diff(&multi) < 1e-12, "threads={threads}");
    }
}
