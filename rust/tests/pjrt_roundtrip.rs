//! Integration: AOT artifacts execute on PJRT and agree with CPU engines.

use unifrac::embed::{collect_batches, EmbeddingKind};
use unifrac::matrix::StripeBlock;
use unifrac::runtime::{ArtifactQuery, Runtime};
use unifrac::synth::SynthSpec;
use unifrac::unifrac::{make_engine, EngineKind, Metric};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(dir).expect("runtime opens"))
}

#[test]
fn pallas_artifact_matches_cpu_engine() {
    let Some(rt) = runtime() else { return };
    let q = ArtifactQuery::new(Metric::WeightedNormalized, "float64", "pallas_tiled", 2);
    let exec = rt.executor(&q).expect("executor");
    let a = exec.artifact().clone();

    let (tree, table) = SynthSpec {
        n_samples: a.n_samples.min(48),
        n_features: 256,
        ..Default::default()
    }
    .generate();
    let batches = collect_batches::<f64>(
        &tree, &table, EmbeddingKind::Proportion, a.n_samples, a.emb_batch,
    )
    .unwrap();

    let mut pjrt_block = StripeBlock::<f64>::new(a.n_samples, 0, a.n_stripes);
    for b in &batches {
        exec.update(b, &mut pjrt_block).expect("pjrt update");
    }

    let engine = make_engine::<f64>(EngineKind::Tiled, 16);
    let mut cpu_block = StripeBlock::<f64>::new(a.n_samples, 0, a.n_stripes);
    for b in &batches {
        engine.apply(Metric::WeightedNormalized, b, &mut cpu_block);
    }

    let diff = pjrt_block.max_abs_diff(&cpu_block);
    assert!(diff < 1e-9, "pjrt vs cpu diff {diff}");
}

#[test]
fn coordinator_pjrt_matches_cpu_all_modes() {
    use unifrac::coordinator::{run, Backend, RunOptions};
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let (tree, table) =
        SynthSpec { n_samples: 40, n_features: 256, ..Default::default() }.generate();
    let cpu = run::<f64>(
        &tree,
        &table,
        &RunOptions { artifacts_dir: None, ..Default::default() },
    )
    .unwrap();
    for artifact in ["pallas_tiled", "jnp"] {
        for resident in [false, true] {
            let opts = RunOptions {
                backend: Backend::Pjrt { artifact: artifact.into(), resident },
                artifacts_dir: Some(dir.clone()),
                parallel: false,
                ..Default::default()
            };
            let out = run::<f64>(&tree, &table, &opts).unwrap();
            let diff = out.dm.max_abs_diff(&cpu.dm);
            assert!(diff < 1e-9, "{artifact} resident={resident}: diff {diff}");
        }
    }
}

#[test]
fn coordinator_pjrt_multichip_parallel() {
    use unifrac::coordinator::{run, Backend, RunOptions};
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let (tree, table) =
        SynthSpec { n_samples: 32, n_features: 128, ..Default::default() }.generate();
    let cpu = run::<f64>(
        &tree,
        &table,
        &RunOptions { artifacts_dir: None, ..Default::default() },
    )
    .unwrap();
    let opts = RunOptions {
        backend: Backend::Pjrt { artifact: "jnp".into(), resident: true },
        artifacts_dir: Some(dir),
        chips: 2,
        parallel: true,
        ..Default::default()
    };
    let out = run::<f64>(&tree, &table, &opts).unwrap();
    assert!(out.dm.max_abs_diff(&cpu.dm) < 1e-9);
    assert_eq!(out.metrics.per_chip_seconds.len(), 2);
}
