//! Property suite for the fault-tolerant stripe fleet (ISSUE 7): every
//! deterministically injected failure — killed workers, truncated and
//! bit-flipped partials, stragglers, a halted supervisor — must either
//! converge to a matrix **bit-identical** (max abs diff == 0) to the
//! single-process run, or fail with a typed error. Corrupted `UFPR`
//! partials must be rejected by their CRC32C checksum and recomputed,
//! never merged.
//!
//! Workers are real subprocesses: each test re-invokes the compiled
//! `unifrac` binary's `worker` subcommand via `CARGO_BIN_EXE_unifrac`.

use std::path::PathBuf;
use std::time::Duration;

use unifrac::api::{FpWidth, JobSpec, UniFracJob};
use unifrac::distrib::{supervise, FaultPlan, FleetSpec};
use unifrac::error::Error;
use unifrac::matrix::{CondensedFile, CondensedMatrix, OutputFormat};
use unifrac::synth::SynthSpec;
use unifrac::table::{write_table_tsv, FeatureTable};
use unifrac::tree::{write_newick, Phylogeny};
use unifrac::unifrac::{EngineKind, Metric};

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_unifrac"))
}

/// A synthetic problem written to disk (workers reload it) plus the
/// in-memory handles for the reference run.
struct Scene {
    dir: PathBuf,
    table_path: PathBuf,
    tree_path: PathBuf,
    tree: Phylogeny,
    table: FeatureTable,
}

impl Scene {
    fn new(tag: &str, n_samples: usize, seed: u64) -> Self {
        let dir = std::env::temp_dir()
            .join(format!("unifrac_distrib_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (tree, table) = SynthSpec {
            n_samples,
            n_features: 96,
            density: 0.15,
            seed,
            ..Default::default()
        }
        .generate();
        let table_path = dir.join("t.tsv");
        let tree_path = dir.join("t.nwk");
        write_table_tsv(&table, &table_path).unwrap();
        std::fs::write(&tree_path, write_newick(&tree)).unwrap();
        Self { dir, table_path, tree_path, tree, table }
    }

    fn fleet(&self, output: &str) -> FleetSpec {
        FleetSpec {
            table: self.table_path.clone(),
            tree: self.tree_path.clone(),
            output: self.dir.join(output),
            workers: 4,
            backoff_base_ms: 5,
            backoff_cap_ms: 50,
            worker_program: Some(worker_bin()),
            ..Default::default()
        }
    }

    fn reference(&self, spec: &JobSpec) -> CondensedMatrix {
        UniFracJob::with_spec(&self.tree, &self.table, spec.clone()).run().unwrap()
    }
}

impl Drop for Scene {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn open_matrix(path: &std::path::Path) -> CondensedMatrix {
    CondensedFile::open(path).unwrap().to_matrix()
}

#[test]
fn clean_fleet_is_bit_identical_across_engines_and_precisions() {
    let wn = Metric::parse("weighted_normalized", 1.0).unwrap();
    let uw = Metric::parse("unweighted", 1.0).unwrap();
    let wu = Metric::parse("weighted_unnormalized", 1.0).unwrap();
    let configs: [(&str, Metric, EngineKind, FpWidth); 3] = [
        ("wn_tiled_f64", wn, EngineKind::Tiled, FpWidth::F64),
        ("uw_packed_f32", uw, EngineKind::Packed, FpWidth::F32),
        ("wu_sparse_f64", wu, EngineKind::Sparse, FpWidth::F64),
    ];
    for (tag, metric, engine, precision) in configs {
        let scene = Scene::new(tag, 26, 3);
        let spec = JobSpec {
            metric,
            engine: Some(engine),
            precision,
            output_format: OutputFormat::Tsv,
            ..Default::default()
        };
        let fleet = scene.fleet("dm.tsv");
        let report = supervise(&scene.tree, &scene.table, &spec, &fleet)
            .unwrap_or_else(|e| panic!("{tag}: fleet failed: {e}"));
        assert!(!report.halted);
        assert_eq!(report.stripes_computed, report.stripes_total, "{tag}");
        // byte-for-byte: the fleet's TSV equals the in-memory run's TSV
        let full = scene.reference(&spec);
        let ref_path = scene.dir.join("ref.tsv");
        full.write_tsv(&ref_path).unwrap();
        let got = std::fs::read(&fleet.output).unwrap();
        let want = std::fs::read(&ref_path).unwrap();
        assert_eq!(got, want, "{tag}: fleet TSV differs from single-process TSV");
    }
}

#[test]
fn killed_worker_and_bit_flip_converge_bit_identical() {
    let scene = Scene::new("kill_flip", 26, 5);
    let spec = JobSpec { output_format: OutputFormat::Mmap, ..Default::default() };
    let mut fleet = scene.fleet("dm.ufdm");
    // kill the worker holding stripe 1; flip a payload bit in the
    // partial covering stripe 5 (its CRC must catch the flip)
    fleet.fault = Some(FaultPlan::parse("kill@1;flip@5", 42).unwrap());
    let report = supervise(&scene.tree, &scene.table, &spec, &fleet).unwrap();
    assert!(!report.halted);
    assert!(report.shards_failed >= 1, "the killed worker must be observed: {report:?}");
    assert!(report.corrupt_rejected >= 1, "the flipped partial must be rejected: {report:?}");
    assert!(report.retries >= 2, "both faults must re-queue their shard: {report:?}");
    let full = scene.reference(&spec);
    let f = CondensedFile::open(&fleet.output).unwrap();
    assert_eq!(f.version(), 2);
    assert!(f.checksummed());
    assert_eq!(f.to_matrix().max_abs_diff(&full), 0.0, "fleet result must be bit-identical");
}

#[test]
fn truncated_partial_is_rejected_by_checksum_and_recomputed() {
    let scene = Scene::new("truncate", 24, 9);
    let spec = JobSpec { output_format: OutputFormat::Mmap, ..Default::default() };
    let mut fleet = scene.fleet("dm.ufdm");
    fleet.fault = Some(FaultPlan::parse("truncate@2:24", 42).unwrap());
    let report = supervise(&scene.tree, &scene.table, &spec, &fleet).unwrap();
    assert!(report.corrupt_rejected >= 1, "torn partial must be rejected: {report:?}");
    let full = scene.reference(&spec);
    assert_eq!(open_matrix(&fleet.output).max_abs_diff(&full), 0.0);
}

#[test]
fn straggler_times_out_and_its_shard_requeues() {
    let scene = Scene::new("straggler", 24, 13);
    let spec = JobSpec { output_format: OutputFormat::Mmap, ..Default::default() };
    let mut fleet = scene.fleet("dm.ufdm");
    fleet.timeout = Duration::from_millis(400);
    fleet.fault = Some(FaultPlan::parse("delay@0:30000", 42).unwrap());
    let report = supervise(&scene.tree, &scene.table, &spec, &fleet).unwrap();
    assert!(report.timeouts >= 1, "the delayed worker must be killed: {report:?}");
    assert!(report.retries >= 1, "its shard must re-queue: {report:?}");
    let full = scene.reference(&spec);
    assert_eq!(open_matrix(&fleet.output).max_abs_diff(&full), 0.0);
}

#[test]
fn halted_supervisor_resumes_from_coverage_bitmap() {
    let scene = Scene::new("halt_resume", 26, 17);
    let spec = JobSpec { output_format: OutputFormat::Mmap, ..Default::default() };
    let mut fleet = scene.fleet("dm.ufdm");
    fleet.workers = 2;
    fleet.fault = Some(FaultPlan::parse("halt@1", 42).unwrap());
    let halted = supervise(&scene.tree, &scene.table, &spec, &fleet).unwrap();
    assert!(halted.halted, "halt@1 must stop the fleet early");
    assert!(
        halted.stripes_computed < halted.stripes_total,
        "a halted fleet must leave work: {halted:?}"
    );
    // the unfinalized file must be rejected as a finished matrix...
    assert!(CondensedFile::open(&fleet.output).is_err(), "halted output must not read as done");
    // ...and a faultless re-run at the same path resumes, not recomputes
    fleet.fault = None;
    let resumed = supervise(&scene.tree, &scene.table, &spec, &fleet).unwrap();
    assert!(!resumed.halted);
    assert!(resumed.stripes_resumed >= halted.stripes_computed, "{resumed:?}");
    assert_eq!(
        resumed.stripes_resumed + resumed.stripes_computed,
        resumed.stripes_total,
        "{resumed:?}"
    );
    let full = scene.reference(&spec);
    assert_eq!(open_matrix(&fleet.output).max_abs_diff(&full), 0.0);
}

#[test]
fn retry_exhaustion_fails_with_typed_error_and_no_output() {
    let scene = Scene::new("exhaust", 20, 21);
    let spec = JobSpec { output_format: OutputFormat::Mmap, ..Default::default() };
    let mut fleet = scene.fleet("dm.ufdm");
    // a "worker" that always exits non-zero with a code outside the
    // fatal set: retryable every time, so the shard's retry budget is
    // what ends the fleet
    fleet.worker_program = Some(PathBuf::from("/bin/false"));
    fleet.max_retries = 1;
    let err = supervise(&scene.tree, &scene.table, &spec, &fleet)
        .err()
        .expect("a fleet whose workers always fail must give up");
    match err {
        Error::Invalid(msg) => assert!(msg.contains("giving up"), "unexpected message: {msg}"),
        other => panic!("retry exhaustion must be Invalid, got: {other}"),
    }
    // the sink abandoned a zero-progress file rather than leaving junk
    assert!(!fleet.output.exists(), "failed fleet must not leave a zero-progress output");
}

#[test]
fn fatal_worker_exit_fails_fast_without_retries() {
    let scene = Scene::new("fatal", 20, 23);
    // sabotage determinism: point workers at a table file that does not
    // parse, so every worker exits with the Table error code (12, fatal)
    let bad = scene.dir.join("bad.tsv");
    std::fs::write(&bad, "this is not a feature table\n").unwrap();
    let spec = JobSpec { output_format: OutputFormat::Mmap, ..Default::default() };
    let mut fleet = scene.fleet("dm.ufdm");
    fleet.table = bad;
    let err = supervise(&scene.tree, &scene.table, &spec, &fleet)
        .err()
        .expect("deterministic worker failure must fail the fleet");
    match err {
        Error::Invalid(msg) => {
            assert!(msg.contains("fatally"), "should report the fatal exit: {msg}")
        }
        other => panic!("fatal exit must be Invalid, got: {other}"),
    }
}

#[test]
fn worker_exit_codes_are_the_stable_error_codes() {
    // the supervisor's classify_exit contract only holds if the worker
    // subcommand actually exits with Error::code values
    let exe = worker_bin();
    let dir = std::env::temp_dir()
        .join(format!("unifrac_distrib_codes_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // missing table file -> Io (10)
    let out = std::process::Command::new(&exe)
        .args(["worker", "--table", "/nonexistent/t.tsv", "--tree", "/nonexistent/t.nwk"])
        .args(["--start", "0", "--count", "1", "--out"])
        .arg(dir.join("p.ufpr"))
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(10), "missing input must exit with the Io code");
    // missing required flag -> Cli (19): deterministic, a retry loop
    // must classify it fatal rather than spin
    let out = std::process::Command::new(&exe).args(["worker"]).output().unwrap();
    assert_eq!(out.status.code(), Some(19), "usage error must exit with the Cli code");
    std::fs::remove_dir_all(&dir).ok();
}
