//! Property-based tests: metric/algorithm invariants over randomized
//! workloads (seeded xoshiro sweeps — the offline stand-in for proptest).

use unifrac::matrix::CondensedMatrix;
use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::unifrac::{compute_unifrac, ComputeOptions, EngineKind, Metric};
use unifrac::util::Xoshiro256;

fn workload(n: usize, seed: u64) -> (Phylogeny, FeatureTable) {
    SynthSpec {
        n_samples: n,
        n_features: 128,
        density: 0.08,
        seed,
        ..Default::default()
    }
    .generate()
}

fn compute(tree: &Phylogeny, table: &FeatureTable, metric: Metric) -> CondensedMatrix {
    compute_unifrac::<f64>(tree, table, &ComputeOptions { metric, ..Default::default() })
        .expect("compute")
}

/// Distances are within [0, 1] for the normalized metrics and >= 0 for
/// all, for every random workload.
#[test]
fn prop_distances_bounded() {
    for seed in 0..8u64 {
        let (tree, table) = workload(14 + (seed as usize % 5), seed);
        for metric in Metric::all(0.5) {
            let dm = compute(&tree, &table, metric);
            for &d in dm.condensed() {
                assert!(d >= 0.0, "{metric} seed {seed}: negative {d}");
                // weighted_unnormalized and its EMD restatement are the
                // two length-scaled (unbounded) metrics
                if metric != Metric::WeightedUnnormalized && metric != Metric::Emd {
                    assert!(d <= 1.0 + 1e-9, "{metric} seed {seed}: {d} > 1");
                }
            }
        }
    }
}

/// Permuting the sample order permutes the matrix consistently:
/// d_perm(i, j) == d(p(i), p(j)).
#[test]
fn prop_sample_permutation_equivariance() {
    for seed in 0..5u64 {
        let (tree, table) = workload(12, seed);
        let dm = compute(&tree, &table, Metric::WeightedNormalized);
        let mut perm: Vec<usize> = (0..12).collect();
        Xoshiro256::new(seed ^ 0xF00).shuffle(&mut perm);
        let permuted_table = table.select_samples(&perm).expect("select");
        let dm_p = compute(&tree, &permuted_table, Metric::WeightedNormalized);
        for i in 0..12 {
            for j in (i + 1)..12 {
                let a = dm_p.get(i, j);
                let b = dm.get(perm[i], perm[j]);
                assert!(
                    (a - b).abs() < 1e-10,
                    "seed {seed}: perm({i},{j}) = {a} vs original {b}"
                );
            }
        }
    }
}

/// Scaling every count of a sample by a constant leaves all metrics
/// unchanged (they consume relative abundances / presence).
#[test]
fn prop_count_scale_invariance() {
    let (tree, table) = workload(10, 3);
    let scaled_rows: Vec<Vec<(u32, f64)>> = (0..table.n_samples())
        .map(|s| {
            let (idx, val) = table.row(s);
            let factor = (s + 1) as f64 * 7.5;
            idx.iter().zip(val).map(|(&f, &v)| (f, v * factor)).collect()
        })
        .collect();
    let scaled = FeatureTable::from_rows(
        table.sample_ids().to_vec(),
        table.feature_ids().to_vec(),
        scaled_rows,
    )
    .unwrap();
    for metric in Metric::all(0.5) {
        let a = compute(&tree, &table, metric);
        let b = compute(&tree, &scaled, metric);
        assert!(a.max_abs_diff(&b) < 1e-10, "{metric} not scale invariant");
    }
}

/// Scaling all branch lengths by c leaves normalized metrics unchanged
/// and scales weighted_unnormalized exactly by c.
#[test]
fn prop_branch_length_scaling() {
    use unifrac::tree::{parse_newick, write_newick};
    let (tree, table) = workload(10, 4);
    // scale by rewriting the newick with doubled lengths
    let doubled = {
        let nwk = write_newick(&tree);
        let t = parse_newick(&nwk).unwrap();
        // rebuild with doubled lengths via builder
        let mut b = unifrac::tree::PhylogenyBuilder::new();
        let mut map = std::collections::HashMap::new();
        for &node in t.postorder().iter().rev() {
            // preorder: parents before children
            let parent = t
                .parent(node)
                .map(|p| *map.get(&p).expect("parent mapped"))
                .unwrap_or(unifrac::tree::NO_PARENT);
            let id = b.add_node(
                parent,
                t.branch_length(node) * 2.0,
                t.name(node).map(String::from),
            );
            map.insert(node, id);
        }
        b.build().unwrap()
    };
    for metric in [Metric::Unweighted, Metric::WeightedNormalized, Metric::Generalized(0.5)] {
        let a = compute(&tree, &table, metric);
        let b = compute(&doubled, &table, metric);
        assert!(a.max_abs_diff(&b) < 1e-10, "{metric} not length-scale invariant");
    }
    for metric in [Metric::WeightedUnnormalized, Metric::Emd] {
        let a = compute(&tree, &table, metric);
        let b = compute(&doubled, &table, metric);
        for (x, y) in a.condensed().iter().zip(b.condensed()) {
            assert!((y - 2.0 * x).abs() < 1e-9, "{metric} should scale: {x} -> {y}");
        }
    }
}

/// Unweighted UniFrac is a proper metric: triangle inequality holds.
#[test]
fn prop_unweighted_triangle_inequality() {
    for seed in 0..6u64 {
        let (tree, table) = workload(12, seed + 100);
        let dm = compute(&tree, &table, Metric::Unweighted);
        let n = dm.n_samples();
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let lhs = dm.get(i, j);
                    let rhs = dm.get(i, k) + dm.get(k, j);
                    assert!(
                        lhs <= rhs + 1e-9,
                        "seed {seed}: d({i},{j})={lhs} > d({i},{k})+d({k},{j})={rhs}"
                    );
                }
            }
        }
    }
}

/// Engines agree pairwise on random problems across batch sizes, thread
/// counts and tile widths (the cross-engine consistency property).
#[test]
fn prop_engine_consistency_sweep() {
    let mut rng = Xoshiro256::new(0xABCDE);
    for round in 0..6 {
        let n = 8 + rng.below(40);
        let (tree, table) = workload(n, round as u64 + 50);
        let metric = {
            let all = Metric::all(0.5);
            all[rng.below(all.len())]
        };
        let base = compute(&tree, &table, metric);
        // draw an engine compatible with the metric (packed is
        // unweighted-only, sparse is weighted-only)
        let engine = loop {
            let all = EngineKind::all();
            let k = all[rng.below(all.len())];
            if k.supports(metric) {
                break k;
            }
        };
        let opts = ComputeOptions {
            metric,
            engine: Some(engine),
            block_k: [8, 13, 32, 64][rng.below(4)],
            batch_capacity: 1 + rng.below(40),
            threads: 1 + rng.below(4),
            ..Default::default()
        };
        let other = compute_unifrac::<f64>(&tree, &table, &opts).expect("variant");
        let diff = base.max_abs_diff(&other);
        assert!(diff < 1e-10, "round {round} ({metric}, {opts:?}): diff {diff}");
    }
}

/// Generalized UniFrac at alpha = 1 degenerates to weighted_normalized
/// (the VAW family's closed endpoint): < 1e-12 on random workloads.
#[test]
fn prop_generalized_alpha_one_is_weighted_normalized() {
    for seed in 0..4u64 {
        let (tree, table) = workload(12, seed + 200);
        let gen1 = compute(&tree, &table, Metric::Generalized(1.0));
        let wn = compute(&tree, &table, Metric::WeightedNormalized);
        let diff = gen1.max_abs_diff(&wn);
        assert!(diff < 1e-12, "seed {seed}: alpha=1 drifts {diff:e} from weighted_normalized");
    }
}

/// Generalized UniFrac at alpha = 0 (the pure-proportion endpoint) is a
/// valid bounded metric and every supporting engine agrees on it.
#[test]
fn prop_generalized_alpha_zero_engines_agree() {
    let (tree, table) = workload(12, 77);
    let metric = Metric::Generalized(0.0);
    let base = compute(&tree, &table, metric);
    for &d in base.condensed() {
        assert!((0.0..=1.0 + 1e-9).contains(&d), "alpha=0 out of range: {d}");
    }
    for engine in EngineKind::all() {
        if !engine.supports(metric) {
            continue;
        }
        let dm = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { metric, engine: Some(engine), ..Default::default() },
        )
        .unwrap();
        let diff = base.max_abs_diff(&dm);
        assert!(diff < 1e-12, "{} disagrees at alpha=0 by {diff:e}", engine.name());
    }
}

/// Non-finite or negative alpha is rejected as a typed `Invalid` error
/// before any engine runs — at job resolution, for every engine choice.
#[test]
fn prop_generalized_bad_alpha_rejected() {
    let (tree, table) = workload(8, 5);
    for alpha in [-0.25, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        let err = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { metric: Metric::Generalized(alpha), ..Default::default() },
        )
        .expect_err("bad alpha must not compute");
        assert!(
            matches!(err, unifrac::Error::Invalid(_)),
            "alpha={alpha}: wrong error {err:?}"
        );
    }
}

/// Adding an empty (all-zero) feature column never changes distances.
#[test]
fn prop_empty_feature_irrelevant() {
    let (tree, table) = workload(10, 9);
    let a = compute(&tree, &table, Metric::WeightedNormalized);
    // extend the tree with an extra leaf that no sample contains:
    // graft "GHOST" onto the root with some length
    let nwk = unifrac::tree::write_newick(&tree);
    let grafted = format!("({},GHOST:3.25);", nwk.trim_end_matches(';'));
    let tree2 = unifrac::tree::parse_newick(&grafted).unwrap();
    let b = compute(&tree2, &table, Metric::WeightedNormalized);
    assert!(a.max_abs_diff(&b) < 1e-10, "ghost feature changed distances");
}
