#!/usr/bin/env python3
"""Regenerate the committed v1 (pre-checksum) format fixtures.

The v2 readers in `api/partial.rs` and `matrix/{sink,view}.rs` must keep
loading v1 `UFPR` / `UFDM` files (written by releases before ISSUE 7
added CRC32C checksums) with `checksummed == false`. The current Rust
writers only emit v2, so the v1 bytes are synthesized here, byte by
byte, from the frozen v1 layouts:

  UFPR v1:  "UFPR" | u16 version=1 | u8 fp_bytes | str metric |
            f64 alpha | str engine | u64 n_samples | u64 padded_n |
            u64 stripe_start | u64 stripe_count | u32 n_ids | ids... |
            num payload | den payload        (str = u32 len + bytes)

  UFDM v1:  64-byte prologue (magic, u16 version=1, u8 fp, u8 flags,
            u64 n_samples, u64 padded_n, u64 stripes_total,
            u64 bitmap_off, u64 payload_off, f64 alpha,
            u8 metric_len, 7 reserved) | metric name at offset 64 |
            ids (u32 count, per id u32 len + bytes) | coverage bitmap |
            zero pad to 8-aligned payload_off | n*(n-1)/2 f64 distances

`tests/format_compat.rs` asserts against the exact values below, so a
regeneration is byte-identical to the committed fixtures.
"""

import struct
from pathlib import Path

HERE = Path(__file__).resolve().parent


def put_str(buf: bytearray, s: str) -> None:
    buf += struct.pack("<I", len(s))
    buf += s.encode("ascii")


def make_ufpr_v1() -> bytes:
    n_samples = 8
    padded_n = 8
    start, count = 0, 4
    buf = bytearray()
    buf += b"UFPR"
    buf += struct.pack("<H", 1)  # version 1: no CRC fields
    buf += struct.pack("<B", 8)  # fp width: f64
    put_str(buf, "weighted_normalized")
    buf += struct.pack("<d", 1.0)  # alpha
    put_str(buf, "tiled")
    buf += struct.pack("<QQQQ", n_samples, padded_n, start, count)
    ids = [f"s{i}" for i in range(n_samples)]
    buf += struct.pack("<I", len(ids))
    for sid in ids:
        put_str(buf, sid)
    cells = count * padded_n
    for i in range(cells):  # numerators
        buf += struct.pack("<d", (i + 1) * 0.5)
    for _ in range(cells):  # denominators
        buf += struct.pack("<d", 100.0)
    return bytes(buf)


def make_ufdm_v1() -> bytes:
    n_samples = 5
    padded_n = 8
    stripes_total = padded_n // 2
    metric = b"weighted_normalized"
    ids = [f"s{i}" for i in range(n_samples)]
    ids_len = 4 + sum(4 + len(s) for s in ids)
    bitmap_off = 64 + len(metric) + ids_len
    bitmap_bytes = (stripes_total + 7) // 8
    payload_off = (bitmap_off + bitmap_bytes + 7) & ~7
    buf = bytearray()
    buf += b"UFDM"
    buf += struct.pack("<H", 1)  # version 1: metric at offset 64, no CRCs
    buf += struct.pack("<BB", 8, 1)  # fp width f64, flags: FINALIZED
    buf += struct.pack("<QQQ", n_samples, padded_n, stripes_total)
    buf += struct.pack("<QQ", bitmap_off, payload_off)
    buf += struct.pack("<d", 1.0)  # alpha
    buf += struct.pack("<B", len(metric))
    buf += b"\0" * 7  # reserved
    assert len(buf) == 64
    buf += metric
    buf += struct.pack("<I", len(ids))
    for sid in ids:
        buf += struct.pack("<I", len(sid)) + sid.encode("ascii")
    assert len(buf) == bitmap_off
    buf += bytes([0x0F])  # all 4 stripes flushed
    buf += b"\0" * (payload_off - len(buf))
    n_pairs = n_samples * (n_samples - 1) // 2
    for idx in range(n_pairs):
        buf += struct.pack("<d", (idx + 1) / 16.0)
    return bytes(buf)


def main() -> None:
    ufpr = make_ufpr_v1()
    ufdm = make_ufdm_v1()
    (HERE / "partial_v1.ufpr").write_bytes(ufpr)
    (HERE / "matrix_v1.ufdm").write_bytes(ufdm)
    print(f"partial_v1.ufpr: {len(ufpr)} bytes")
    print(f"matrix_v1.ufdm:  {len(ufdm)} bytes")


if __name__ == "__main__":
    main()
