//! Query server behavior: protocol round trip, typed overload paths,
//! fault injection, and graceful drain (ISSUE 8).
//!
//! Every test binds `127.0.0.1:0` (kernel-assigned port), so the suite
//! is safe to run in parallel with itself.

use std::path::{Path, PathBuf};

use unifrac::distrib::FaultPlan;
use unifrac::embed::EmbeddingKind;
use unifrac::service::server::error_from_response;
use unifrac::service::{query, request_line, QuerySpec, ReferenceSet, ServeConfig, Server};
use unifrac::synth::SynthSpec;
use unifrac::table::{write_table_tsv, FeatureTable};
use unifrac::util::json::{self, Json};
use unifrac::{Error, FpWidth, Metric};

const N_REF: usize = 16;
const K: usize = 5;

struct Fixture {
    dir: PathBuf,
    ref_path: String,
    table_path: String,
    refset: ReferenceSet,
    query_table: FeatureTable,
}

fn fixture(name: &str) -> Fixture {
    let dir = std::env::temp_dir().join(format!("unifrac_serve_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (tree, combined) = SynthSpec {
        n_samples: N_REF + K,
        n_features: 128,
        density: 0.12,
        seed: 909,
        ..Default::default()
    }
    .generate();
    let ref_table = combined.select_samples(&(0..N_REF).collect::<Vec<_>>()).unwrap();
    let query_table =
        combined.select_samples(&(N_REF..N_REF + K).collect::<Vec<_>>()).unwrap();
    let refset = ReferenceSet::snapshot(&tree, &ref_table, EmbeddingKind::Presence).unwrap();
    let ref_path = dir.join("ref.ufrs");
    refset.save(&ref_path).unwrap();
    let table_path = dir.join("query.tsv");
    write_table_tsv(&query_table, &table_path).unwrap();
    Fixture {
        ref_path: ref_path.to_string_lossy().into_owned(),
        table_path: table_path.to_string_lossy().into_owned(),
        dir,
        refset,
        query_table,
    }
}

fn cfg(fault: &str) -> ServeConfig {
    ServeConfig {
        workers: 2,
        queue_depth: 8,
        cache_bytes: 64 << 20,
        deadline_ms: 0,
        drain_ms: 500,
        io_timeout_ms: 5000,
        fault: FaultPlan::parse(fault, 0).unwrap(),
    }
}

fn query_req(fx: &Fixture) -> String {
    json::obj(vec![
        ("op", Json::Str("query".into())),
        ("ref", Json::Str(fx.ref_path.clone())),
        ("table", Json::Str(fx.table_path.clone())),
        ("metric", Json::Str("unweighted".into())),
    ])
    .dump()
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn tcp_roundtrip_matches_offline_bit_for_bit() {
    let fx = fixture("roundtrip");
    let server = Server::start(Some("127.0.0.1:0"), None, cfg("")).unwrap();
    let addr = server.local_addr().unwrap().to_string();

    let resp = request_line(&addr, &query_req(&fx), 10_000).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(matches!(j.get("ok"), Ok(Json::Bool(true))), "{resp}");
    let got = query::output_from_json(&j).unwrap();

    let spec = QuerySpec::new(Metric::Unweighted, FpWidth::F64);
    let want = query::run(&fx.refset, &fx.query_table, &spec).unwrap();
    assert_eq!(got.query_ids, want.query_ids);
    assert_eq!(got.ref_ids, want.ref_ids);
    for (x, y) in got.distances.iter().zip(&want.distances) {
        assert_eq!(x.to_bits(), y.to_bits(), "wire hop must be lossless");
    }

    // health + stats ops answer on the same keep-alive protocol
    let h = Json::parse(&request_line(&addr, r#"{"op":"health"}"#, 10_000).unwrap()).unwrap();
    assert_eq!(h.get("status").ok().and_then(Json::as_str), Some("ok"));
    let s = Json::parse(&request_line(&addr, r#"{"op":"stats"}"#, 10_000).unwrap()).unwrap();
    assert!(s.get("completed").ok().and_then(Json::as_f64).unwrap() >= 1.0);

    // unknown op and bad JSON are typed errors, not dropped connections
    let b = Json::parse(&request_line(&addr, r#"{"op":"nope"}"#, 10_000).unwrap()).unwrap();
    assert!(matches!(b.get("ok"), Ok(Json::Bool(false))));
    let b = Json::parse(&request_line(&addr, "{not json", 10_000).unwrap()).unwrap();
    assert!(matches!(b.get("ok"), Ok(Json::Bool(false))));

    server.begin_shutdown();
    let stats = server.join();
    assert!(stats.completed >= 1);
    assert_eq!(stats.shed, 0);
    cleanup(&fx.dir);
}

#[test]
fn reject_fault_sheds_with_code_23() {
    let fx = fixture("reject");
    // connection #0 is rejected at admission; #1 succeeds
    let server = Server::start(Some("127.0.0.1:0"), None, cfg("reject@0")).unwrap();
    let addr = server.local_addr().unwrap().to_string();

    let resp = request_line(&addr, &query_req(&fx), 10_000).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(matches!(j.get("ok"), Ok(Json::Bool(false))), "{resp}");
    assert_eq!(j.get("code").ok().and_then(Json::as_f64), Some(23.0));
    let e = error_from_response(&j);
    assert!(matches!(e, Error::Overloaded(_)));
    assert_eq!(e.code(), 23);

    let resp = request_line(&addr, &query_req(&fx), 10_000).unwrap();
    assert!(matches!(Json::parse(&resp).unwrap().get("ok"), Ok(Json::Bool(true))));

    server.begin_shutdown();
    let stats = server.join();
    assert_eq!(stats.shed, 1);
    cleanup(&fx.dir);
}

#[test]
fn drop_conn_fault_is_an_io_error_not_a_shed() {
    let fx = fixture("drop");
    let server = Server::start(Some("127.0.0.1:0"), None, cfg("drop-conn@0")).unwrap();
    let addr = server.local_addr().unwrap().to_string();

    let err = request_line(&addr, &query_req(&fx), 10_000).unwrap_err();
    assert!(matches!(err, Error::Io(_)), "dropped conn must be Io, got {err}");
    assert_ne!(err.code(), 23);

    // the next connection is unaffected (single-fire fault)
    let resp = request_line(&addr, &query_req(&fx), 10_000).unwrap();
    assert!(matches!(Json::parse(&resp).unwrap().get("ok"), Ok(Json::Bool(true))));

    server.begin_shutdown();
    server.join();
    cleanup(&fx.dir);
}

#[test]
fn slowref_plus_deadline_exceeds_with_code_24() {
    let fx = fixture("deadline");
    // connection #0 sleeps 300ms before touching the cache; a 50ms
    // request deadline must fire with code 24, not run to completion
    let server = Server::start(Some("127.0.0.1:0"), None, cfg("slowref@0:300")).unwrap();
    let addr = server.local_addr().unwrap().to_string();

    let req = json::obj(vec![
        ("op", Json::Str("query".into())),
        ("ref", Json::Str(fx.ref_path.clone())),
        ("table", Json::Str(fx.table_path.clone())),
        ("metric", Json::Str("unweighted".into())),
        ("deadline_ms", Json::Num(50.0)),
    ])
    .dump();
    let j = Json::parse(&request_line(&addr, &req, 10_000).unwrap()).unwrap();
    assert_eq!(j.get("code").ok().and_then(Json::as_f64), Some(24.0), "{j:?}");
    assert!(matches!(error_from_response(&j), Error::DeadlineExceeded(_)));

    server.begin_shutdown();
    let stats = server.join();
    assert_eq!(stats.deadline_exceeded, 1);
    cleanup(&fx.dir);
}

#[test]
fn missing_reference_is_a_typed_error_and_corrupt_ref_is_code_22() {
    let fx = fixture("corrupt");
    let server = Server::start(Some("127.0.0.1:0"), None, cfg("")).unwrap();
    let addr = server.local_addr().unwrap().to_string();

    let req = json::obj(vec![
        ("op", Json::Str("query".into())),
        ("ref", Json::Str(fx.dir.join("absent.ufrs").to_string_lossy().into_owned())),
        ("table", Json::Str(fx.table_path.clone())),
    ])
    .dump();
    let j = Json::parse(&request_line(&addr, &req, 10_000).unwrap()).unwrap();
    assert!(matches!(j.get("ok"), Ok(Json::Bool(false))));

    // corrupt the artifact on disk: the server must answer 22, and the
    // single-flight cache must not poison later loads of a fixed file
    let mut bytes = std::fs::read(&fx.ref_path).unwrap();
    let at = bytes.len() - 3;
    bytes[at] ^= 0x40;
    let bad_path = fx.dir.join("bad.ufrs");
    std::fs::write(&bad_path, &bytes).unwrap();
    let req = json::obj(vec![
        ("op", Json::Str("query".into())),
        ("ref", Json::Str(bad_path.to_string_lossy().into_owned())),
        ("table", Json::Str(fx.table_path.clone())),
    ])
    .dump();
    let j = Json::parse(&request_line(&addr, &req, 10_000).unwrap()).unwrap();
    assert_eq!(j.get("code").ok().and_then(Json::as_f64), Some(22.0), "{j:?}");

    // the pristine artifact still serves
    let resp = request_line(&addr, &query_req(&fx), 10_000).unwrap();
    assert!(matches!(Json::parse(&resp).unwrap().get("ok"), Ok(Json::Bool(true))));

    server.begin_shutdown();
    server.join();
    cleanup(&fx.dir);
}

#[test]
fn concurrent_clients_all_get_identical_answers() {
    let fx = fixture("concurrent");
    let server = Server::start(Some("127.0.0.1:0"), None, cfg("")).unwrap();
    let addr = server.local_addr().unwrap().to_string();

    let want = {
        let spec = QuerySpec::new(Metric::Unweighted, FpWidth::F64);
        query::run(&fx.refset, &fx.query_table, &spec).unwrap()
    };
    let req = query_req(&fx);
    let handles: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.clone();
            let req = req.clone();
            std::thread::spawn(move || request_line(&addr, &req, 15_000).unwrap())
        })
        .collect();
    for h in handles {
        let j = Json::parse(&h.join().unwrap()).unwrap();
        let got = query::output_from_json(&j).unwrap();
        assert_eq!(got.distances, want.distances);
    }

    server.begin_shutdown();
    let stats = server.join();
    assert!(stats.completed >= 6);
    // six loads of one artifact: single-flight means at most one miss
    assert_eq!(stats.cache_misses, 1);
    assert!(stats.cache_hits >= 5);
    cleanup(&fx.dir);
}

#[test]
fn drain_refuses_new_work_and_join_returns_stats() {
    let fx = fixture("drain");
    let server = Server::start(Some("127.0.0.1:0"), None, cfg("")).unwrap();
    let addr = server.local_addr().unwrap().to_string();

    let resp = request_line(&addr, &query_req(&fx), 10_000).unwrap();
    assert!(matches!(Json::parse(&resp).unwrap().get("ok"), Ok(Json::Bool(true))));

    server.begin_shutdown();
    // after shutdown the listener is gone: connects fail or are reset
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert!(request_line(&addr, &query_req(&fx), 2_000).is_err());
    let stats = server.join();
    assert_eq!(stats.completed, 1);
    cleanup(&fx.dir);
}

#[cfg(unix)]
#[test]
fn unix_socket_roundtrip() {
    let fx = fixture("unix");
    let sock = fx.dir.join("serve.sock");
    let sock_str = sock.to_string_lossy().into_owned();
    let server = Server::start(None, Some(&sock_str), cfg("")).unwrap();

    let addr = format!("unix:{sock_str}");
    let j = Json::parse(&request_line(&addr, &query_req(&fx), 10_000).unwrap()).unwrap();
    assert!(matches!(j.get("ok"), Ok(Json::Bool(true))), "{j:?}");
    let got = query::output_from_json(&j).unwrap();
    let want = query::run(
        &fx.refset,
        &fx.query_table,
        &QuerySpec::new(Metric::Unweighted, FpWidth::F64),
    )
    .unwrap();
    assert_eq!(got.distances, want.distances);

    server.begin_shutdown();
    server.join();
    assert!(!sock.exists(), "socket file must be removed on join");
    cleanup(&fx.dir);
}
