//! Property suite for the sparse CSR weighted engine (ISSUE 3): the
//! sparse kernel must agree with the tiled scalar stage to <1e-12
//! across every weighted metric (several generalized alphas included),
//! the full density range, multi-batch accumulation, and multithreaded
//! (dynamic-scheduler) execution — plus the density-aware auto-selection
//! contract.

use unifrac::exec::SchedulerKind;
use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::unifrac::{
    compute_unifrac, compute_unifrac_report, ComputeOptions, EngineKind, Metric,
};

const DENSITIES: [f64; 4] = [0.01, 0.1, 0.5, 1.0];

fn weighted_metrics() -> Vec<Metric> {
    vec![
        Metric::WeightedNormalized,
        Metric::WeightedUnnormalized,
        Metric::Generalized(0.0),
        Metric::Generalized(0.25),
        Metric::Generalized(0.5),
        Metric::Generalized(1.0),
        Metric::Generalized(1.5),
    ]
}

fn workload(n: usize, density: f64, seed: u64) -> (Phylogeny, FeatureTable) {
    SynthSpec { n_samples: n, n_features: 128, density, seed, ..Default::default() }.generate()
}

fn run(
    tree: &Phylogeny,
    table: &FeatureTable,
    metric: Metric,
    engine: EngineKind,
    batch: usize,
    threads: usize,
    scheduler: SchedulerKind,
) -> unifrac::matrix::CondensedMatrix {
    let opts = ComputeOptions {
        metric,
        engine: Some(engine),
        batch_capacity: batch,
        threads,
        scheduler,
        ..Default::default()
    };
    compute_unifrac::<f64>(tree, table, &opts).expect("compute")
}

#[test]
fn sparse_matches_tiled_all_weighted_metrics_and_densities() {
    for metric in weighted_metrics() {
        for &density in &DENSITIES {
            let (tree, table) = workload(18, density, 7);
            let tiled = run(&tree, &table, metric, EngineKind::Tiled, 32, 1, SchedulerKind::Static);
            let sparse =
                run(&tree, &table, metric, EngineKind::Sparse, 32, 1, SchedulerKind::Static);
            let diff = sparse.max_abs_diff(&tiled);
            assert!(diff < 1e-12, "{metric} density={density}: diff {diff}");
        }
    }
}

#[test]
fn sparse_multi_batch_accumulation_matches_single_batch() {
    // tiny batch capacities force many CSR builds folding into the same
    // stripe accumulators; the result must not depend on the batching
    let (tree, table) = workload(20, 0.1, 11);
    for metric in [Metric::WeightedNormalized, Metric::Generalized(0.5)] {
        let whole = run(&tree, &table, metric, EngineKind::Sparse, 512, 1, SchedulerKind::Static);
        for batch in [1usize, 3, 7, 32] {
            let split =
                run(&tree, &table, metric, EngineKind::Sparse, batch, 1, SchedulerKind::Static);
            let diff = split.max_abs_diff(&whole);
            assert!(diff < 1e-12, "{metric} batch={batch}: diff {diff}");
        }
    }
}

#[test]
fn sparse_multithreaded_dynamic_matches_single_thread() {
    let (tree, table) = workload(26, 0.1, 13);
    for metric in weighted_metrics() {
        let single = run(&tree, &table, metric, EngineKind::Sparse, 8, 1, SchedulerKind::Static);
        for threads in [2usize, 3, 5] {
            for scheduler in [SchedulerKind::Static, SchedulerKind::Dynamic] {
                let multi = run(&tree, &table, metric, EngineKind::Sparse, 8, threads, scheduler);
                let diff = multi.max_abs_diff(&single);
                assert!(
                    diff < 1e-12,
                    "{metric} threads={threads} {scheduler:?}: diff {diff}"
                );
            }
        }
    }
}

#[test]
fn sparse_agrees_with_naive_oracle() {
    let (tree, table) = workload(15, 0.1, 19);
    for metric in weighted_metrics() {
        let oracle =
            unifrac::unifrac::compute_unifrac_naive(&tree, &table, metric).expect("oracle");
        let sparse = run(&tree, &table, metric, EngineKind::Sparse, 16, 1, SchedulerKind::Static);
        let diff = sparse.max_abs_diff(&oracle);
        assert!(diff < 1e-10, "{metric}: diff {diff}");
    }
}

#[test]
fn dense_inputs_auto_select_tiled_sparse_inputs_sparse() {
    // EMP-like sparse input -> sparse engine
    let (tree, table) = workload(16, 0.02, 23);
    let (_, rep) = compute_unifrac_report::<f64>(&tree, &table, &ComputeOptions::default())
        .expect("sparse run");
    assert_eq!(rep.engine, "sparse", "embed_density {}", rep.embed_density);
    assert!(rep.csr_nnz > 0);
    assert!(rep.rows_sparse > 0);
    // dense input -> no regression, tiled stays
    let (tree, table) = workload(16, 1.0, 23);
    let (_, rep) = compute_unifrac_report::<f64>(&tree, &table, &ComputeOptions::default())
        .expect("dense run");
    assert_eq!(rep.engine, "tiled", "embed_density {}", rep.embed_density);
    assert_eq!(rep.csr_nnz, 0);
    assert_eq!(rep.rows_sparse + rep.rows_dense, 0);
}

#[test]
fn sparse_f32_tracks_f64() {
    let (tree, table) = workload(20, 0.1, 29);
    let opts = ComputeOptions {
        metric: Metric::WeightedNormalized,
        engine: Some(EngineKind::Sparse),
        ..Default::default()
    };
    let d64 = compute_unifrac::<f64>(&tree, &table, &opts).expect("f64");
    let d32 = compute_unifrac::<f32>(&tree, &table, &opts).expect("f32");
    assert!(d64.max_abs_diff(&d32) < 1e-4);
    assert!(d64.correlation(&d32) > 0.999999);
}
