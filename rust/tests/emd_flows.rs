//! ISSUE 9 tentpole contracts for the EMDUniFrac metric family:
//!
//! * `Metric::Emd` distances equal `Metric::WeightedUnnormalized` —
//!   bitwise at matching precision (same kernel by construction), and
//!   < 1e-12 across engines and batch shapes against the naive oracle;
//! * the per-pair flow decomposition satisfies the transport laws:
//!   `Σ length·|flow| == distance` and the root's children conserve
//!   mass (signed flows sum to zero);
//! * a hand-checked frozen fixture pins the flows and distances so a
//!   kernel regression cannot silently shift the artifact.

use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::parse_newick;
use unifrac::unifrac::{
    compute_unifrac, compute_unifrac_naive, emd_flows, ComputeOptions, EngineKind,
};
use unifrac::Metric;

fn problem() -> (unifrac::tree::Phylogeny, FeatureTable) {
    SynthSpec { n_samples: 24, n_features: 128, density: 0.12, seed: 13, ..Default::default() }
        .generate()
}

/// Every engine that supports Emd produces the weighted_unnormalized
/// distances: bitwise at the same width, < 1e-12 against the oracle.
#[test]
fn emd_equals_weighted_unnormalized_across_engines() {
    let (tree, table) = problem();
    let oracle = compute_unifrac_naive(&tree, &table, Metric::WeightedUnnormalized).unwrap();
    let oracle_emd = compute_unifrac_naive(&tree, &table, Metric::Emd).unwrap();
    assert_eq!(
        oracle_emd.max_abs_diff(&oracle),
        0.0,
        "naive emd must reuse the weighted_unnormalized kernel exactly"
    );

    for engine in EngineKind::all() {
        if !engine.supports(Metric::Emd) {
            continue;
        }
        let run_f64 = |metric: Metric| {
            compute_unifrac::<f64>(
                &tree,
                &table,
                &ComputeOptions { metric, engine: Some(engine), ..Default::default() },
            )
            .unwrap()
        };
        let emd = run_f64(Metric::Emd);
        let wu = run_f64(Metric::WeightedUnnormalized);
        assert_eq!(
            emd.max_abs_diff(&wu),
            0.0,
            "{}: emd vs weighted_unnormalized must be bitwise identical",
            engine.name()
        );
        let vs_oracle = emd.max_abs_diff(&oracle);
        assert!(vs_oracle < 1e-12, "{}: emd drifts {vs_oracle:e} from oracle", engine.name());

        // f32 width: the two metrics still share every operation
        let run_f32 = |metric: Metric| {
            compute_unifrac::<f32>(
                &tree,
                &table,
                &ComputeOptions { metric, engine: Some(engine), ..Default::default() },
            )
            .unwrap()
        };
        assert_eq!(
            run_f32(Metric::Emd).max_abs_diff(&run_f32(Metric::WeightedUnnormalized)),
            0.0,
            "{}: f32 emd vs f32 weighted_unnormalized",
            engine.name()
        );
    }
}

/// Odd batch capacities exercise the multi-batch streaming path; the
/// equality must not depend on how the embedding stream is chunked.
#[test]
fn emd_equality_holds_across_batch_shapes() {
    let (tree, table) = problem();
    let reference = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions { metric: Metric::Emd, ..Default::default() },
    )
    .unwrap();
    for batch_capacity in [1, 3, 5] {
        let emd = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { metric: Metric::Emd, batch_capacity, ..Default::default() },
        )
        .unwrap();
        let wu = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions {
                metric: Metric::WeightedUnnormalized,
                batch_capacity,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(emd.max_abs_diff(&wu), 0.0, "batch_capacity={batch_capacity}");
        let drift = emd.max_abs_diff(&reference);
        assert!(drift < 1e-12, "batch_capacity={batch_capacity}: drift {drift:e}");
    }
}

/// Transport laws on a synthetic problem: the flow vector reconstructs
/// the matrix distance and conserves mass at the root.
#[test]
fn flows_reconstruct_distance_and_conserve_mass() {
    let (tree, table) = problem();
    let dm = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions { metric: Metric::Emd, ..Default::default() },
    )
    .unwrap();
    let root_kids = tree.children(tree.root()).to_vec();
    for (i, j) in [(0usize, 1usize), (0, 23), (7, 11), (12, 13), (5, 19)] {
        let d = emd_flows(&tree, &table, i, j).unwrap();
        assert_eq!(d.rows.len(), tree.n_nodes() - 1, "one row per non-root node");
        let cost_gap = (d.transport_cost() - d.distance).abs();
        assert!(cost_gap < 1e-12, "pair ({i},{j}): transport cost gap {cost_gap:e}");
        let matrix_gap = (d.distance - dm.get(i, j)).abs();
        assert!(matrix_gap < 1e-12, "pair ({i},{j}): flow-vs-matrix gap {matrix_gap:e}");
        let conservation = d.flow_sum(&root_kids);
        assert!(
            conservation.abs() < 1e-12,
            "pair ({i},{j}): root flows sum to {conservation:e}"
        );
    }
}

/// Frozen fixture: `((A:1,B:2):0.5,C:3);` with hand-derived flows.
/// Pinned so the artifact format and the kernel cannot drift silently.
#[test]
fn frozen_fixture_pins_flows_and_distances() {
    let tree = parse_newick("((A:1,B:2):0.5,C:3);").unwrap();
    let table = FeatureTable::from_dense(
        vec!["s0".into(), "s1".into(), "s2".into()],
        vec!["A".into(), "B".into(), "C".into()],
        &[vec![2.0, 0.0, 0.0], vec![1.0, 1.0, 0.0], vec![0.0, 0.0, 4.0]],
    )
    .unwrap();

    // s0={A:1.0} vs s1={A:.5,B:.5}: A carries +0.5, B carries −0.5,
    // shared AB clade and C are balanced → d = 1·0.5 + 2·0.5 = 1.5
    let d01 = emd_flows(&tree, &table, 0, 1).unwrap();
    assert!((d01.distance - 1.5).abs() < 1e-15, "d(s0,s1) = {}", d01.distance);
    for r in &d01.rows {
        let want = match r.name.as_deref() {
            Some("A") => 0.5,
            Some("B") => -0.5,
            _ => 0.0,
        };
        assert!((r.flow - want).abs() < 1e-15, "{r:?}");
    }
    // the ranked view puts the two movers first, balanced branches drop
    assert_eq!(d01.ranked().len(), 2);

    // s0 vs s2: disjoint clades, all mass crosses the root
    // d = 1·1 (A) + 0.5·1 (AB clade) + 3·1 (C) = 4.5
    let d02 = emd_flows(&tree, &table, 0, 2).unwrap();
    assert!((d02.distance - 4.5).abs() < 1e-15, "d(s0,s2) = {}", d02.distance);
    assert_eq!(d02.ranked()[0].name.as_deref(), Some("C"));

    // the matrix path agrees with both pinned values
    let dm = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions { metric: Metric::Emd, ..Default::default() },
    )
    .unwrap();
    assert!((dm.get(0, 1) - 1.5).abs() < 1e-12);
    assert!((dm.get(0, 2) - 4.5).abs() < 1e-12);
}

/// The metric registry round-trips the new family: name, parse, and
/// engine support (everything except the presence-bit packed engine).
#[test]
fn metric_registry_includes_emd() {
    assert_eq!(Metric::Emd.name(), "emd");
    assert_eq!(Metric::parse("emd", 0.0), Some(Metric::Emd));
    assert!(Metric::all(0.5).contains(&Metric::Emd));
    for engine in EngineKind::all() {
        let supported = engine.supports(Metric::Emd);
        assert_eq!(
            supported,
            engine != EngineKind::Packed,
            "{}: packed is presence-bit only",
            engine.name()
        );
    }
}
