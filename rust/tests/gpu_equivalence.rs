//! GPU stripe-engine conformance suite (ISSUE 10 satellite).
//!
//! Runs entirely on the deterministic **virtual device** — no adapter,
//! no wgpu, no network — so every assertion here executes on any CI
//! host. The vdev interprets the exact dispatch grid, staging layout,
//! and pinned reduction order the WGSL shaders encode, which gives two
//! contracts to pin:
//!
//! * **f64 is exact**: the per-cell ascending-embedding fold matches
//!   the scalar batched engine's grouping, so the device path agrees to
//!   < 1e-12 (and in practice bit-for-bit) with the CPU reference.
//! * **f32 is bounded**: `GPU_F32_TOLERANCE` is the asserted contract
//!   for single-precision device output, not a vague aspiration.
//!
//! Real-adapter cells are `#[ignore]`-gated and print a visible skip
//! notice when no adapter exists, so `cargo test -- --ignored` on a
//! GPU host extends the same suite to silicon.

use unifrac::api::{JobSpec, UniFracJob};
use unifrac::embed::EmbBatch;
use unifrac::exec::SchedulerKind;
use unifrac::matrix::StripeBlock;
use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::unifrac::gpu::{self, KernelPlan, StripeKernel, VirtualDevice};
use unifrac::unifrac::{
    compute_unifrac, compute_unifrac_naive, compute_unifrac_report, ComputeOptions, EngineKind,
    Metric, GPU_F32_TOLERANCE,
};
use unifrac::Error;

fn problem(n: usize, density: f64, seed: u64) -> (Phylogeny, FeatureTable) {
    SynthSpec {
        n_samples: n,
        n_features: (n * 8).max(256),
        density,
        seed,
        ..Default::default()
    }
    .generate()
}

/// Base options for a virtual-device run of the gpu engine — the
/// explicit `"vdev"` adapter is always accepted, adapter or not.
fn vdev_opts(metric: Metric) -> ComputeOptions {
    ComputeOptions {
        metric,
        engine: Some(EngineKind::Gpu),
        gpu_adapter: "vdev".to_string(),
        ..Default::default()
    }
}

/// Scalar CPU reference for the same problem: the batched engine, whose
/// per-cell fold order the virtual device reproduces.
fn cpu_opts(metric: Metric) -> ComputeOptions {
    ComputeOptions { metric, engine: Some(EngineKind::Batched), ..Default::default() }
}

/// Every metric, both precisions: the virtual device agrees with the
/// scalar batched reference — f64 under the 1e-12 contract (expected
/// exact), f32 under the pinned `GPU_F32_TOLERANCE` bound.
#[test]
fn vdev_matches_scalar_reference_all_metrics() {
    let (tree, table) = problem(24, 0.2, 41);
    for metric in Metric::all(0.5) {
        let gpu64 = compute_unifrac::<f64>(&tree, &table, &vdev_opts(metric)).unwrap();
        let cpu64 = compute_unifrac::<f64>(&tree, &table, &cpu_opts(metric)).unwrap();
        let d64 = gpu64.max_abs_diff(&cpu64);
        assert!(d64 < 1e-12, "{metric} f64: gpu/cpu divergence {d64:e} (contract < 1e-12)");

        let gpu32 = compute_unifrac::<f32>(&tree, &table, &vdev_opts(metric)).unwrap();
        let d32 = gpu32.max_abs_diff(&cpu64);
        assert!(
            d32 < GPU_F32_TOLERANCE,
            "{metric} f32: gpu/f64-reference divergence {d32:e} \
             (contract < {GPU_F32_TOLERANCE:e})"
        );
    }
}

/// The device engine produces correct *answers*, not just
/// self-consistent ones: vdev output matches the naive oracle.
#[test]
fn vdev_matches_naive_oracle() {
    let (tree, table) = problem(18, 0.15, 43);
    for metric in Metric::all(0.5) {
        let oracle = compute_unifrac_naive(&tree, &table, metric).unwrap();
        let dev = compute_unifrac::<f64>(&tree, &table, &vdev_opts(metric)).unwrap();
        let diff = dev.max_abs_diff(&oracle);
        assert!(diff < 1e-10, "{metric}: oracle diff {diff:e}");
    }
}

/// Remainder shapes: sample counts and tile widths that do not divide
/// the workgroup grid (n=33 with odd block_k), the minimum problem
/// (n=2, a single stripe), and multi-batch accumulation through tiny
/// batch capacities.
#[test]
fn tile_remainder_and_batch_shapes_agree() {
    // n=33, block_k ∤ padded width → remainder tiles on both grid axes
    let (tree, table) = problem(33, 0.2, 47);
    for &block_k in &[1usize, 13, 64] {
        for &batch_capacity in &[1usize, 7, 64] {
            let base = |engine| ComputeOptions {
                metric: Metric::WeightedNormalized,
                engine: Some(engine),
                gpu_adapter: "vdev".to_string(),
                block_k,
                batch_capacity,
                ..Default::default()
            };
            let dev = compute_unifrac::<f64>(&tree, &table, &base(EngineKind::Gpu)).unwrap();
            let cpu = compute_unifrac::<f64>(&tree, &table, &base(EngineKind::Batched)).unwrap();
            let diff = dev.max_abs_diff(&cpu);
            assert!(
                diff < 1e-12,
                "block_k={block_k} cap={batch_capacity}: divergence {diff:e}"
            );
        }
    }

    // the smallest legal problem: two samples, one stripe
    let (tree2, table2) = problem(2, 0.5, 53);
    let dev = compute_unifrac::<f64>(&tree2, &table2, &vdev_opts(Metric::Unweighted)).unwrap();
    let oracle = compute_unifrac_naive(&tree2, &table2, Metric::Unweighted).unwrap();
    assert!(dev.max_abs_diff(&oracle) < 1e-12, "n=2 single-stripe shape");
}

/// The determinism contract at the kernel level: dispatching the same
/// plan on 1/2/4/8 interpreter threads is **bit-identical** (`== 0.0`),
/// because tiles own disjoint cells and the flush order is pinned.
#[test]
fn vdev_bit_identical_across_kernel_threads() {
    let n = 29;
    let n_stripes = 7;
    let run = |threads: usize| {
        let mut block = StripeBlock::<f64>::new(n, 3, n_stripes);
        let dev = VirtualDevice::with_threads(threads);
        let plan = KernelPlan::new(n, 3, n_stripes, 13, 3);
        for seed in [1u64, 2, 3] {
            let batch = synth_batch(n, 9, seed);
            StripeKernel::<f64>::dispatch(
                &dev,
                &plan,
                Metric::Generalized(0.5),
                &batch,
                &mut block,
            );
        }
        block
    };
    let base = run(1);
    for threads in [2usize, 4, 8] {
        let diff = base.max_abs_diff(&run(threads));
        assert!(diff == 0.0, "threads={threads}: vdev must be bit-identical, diff {diff:e}");
    }
}

/// Hand-built duplicated `[mass|mass]` embedding batch — the staging
/// contract the device plan assumes.
fn synth_batch(n: usize, rows: usize, seed: u64) -> EmbBatch<f64> {
    let mut rng = unifrac::util::Xoshiro256::new(seed);
    let mut batch = EmbBatch {
        n_samples: n,
        filled: rows,
        capacity: rows,
        emb: vec![0.0; rows * 2 * n],
        lengths: vec![0.0; rows],
    };
    for e in 0..rows {
        for k in 0..n {
            let x = if rng.f64() < 0.4 { 0.0 } else { rng.f64() };
            batch.emb[e * 2 * n + k] = x;
            batch.emb[e * 2 * n + n + k] = x;
        }
        batch.lengths[e] = 0.01 + rng.f64();
    }
    batch
}

/// The determinism contract end-to-end: full gpu-engine runs with
/// different worker thread counts and both schedulers are bit-identical.
#[test]
fn vdev_bit_identical_across_pipeline_threads_and_schedulers() {
    let (tree, table) = problem(26, 0.25, 59);
    let run = |threads: usize, scheduler: SchedulerKind| {
        compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions {
                threads,
                scheduler,
                batch_capacity: 8,
                ..vdev_opts(Metric::WeightedUnnormalized)
            },
        )
        .unwrap()
    };
    let base = run(1, SchedulerKind::Static);
    for threads in [1usize, 3] {
        for scheduler in [SchedulerKind::Static, SchedulerKind::Dynamic] {
            let diff = base.max_abs_diff(&run(threads, scheduler));
            assert!(
                diff == 0.0,
                "threads={threads} {scheduler:?}: gpu runs must be bit-identical, diff {diff:e}"
            );
        }
    }
}

/// `--engine gpu` with the default `auto` adapter on a host with no
/// adapter (and no vdev override) is a *typed* `Error::Unsupported`
/// that tells the user how to proceed — never a crash or a silent
/// fallback.
#[test]
fn gpu_engine_without_adapter_is_typed_unsupported() {
    if gpu::adapter_available() || gpu::vdev_forced() {
        eprintln!(
            "SKIP gpu_engine_without_adapter_is_typed_unsupported: \
             a device adapter is available on this host"
        );
        return;
    }
    let (tree, table) = problem(8, 0.3, 61);
    let err = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions {
            engine: Some(EngineKind::Gpu),
            ..Default::default() // gpu_adapter stays "auto"
        },
    )
    .unwrap_err();
    assert!(matches!(err, Error::Unsupported(_)), "got {err:?}");
    let msg = err.to_string();
    assert!(msg.contains("vdev"), "message must route to the virtual device: {msg}");
    assert!(msg.contains(gpu::GPU_VDEV_ENV), "message must name the env override: {msg}");
}

/// `--engine auto` on an adapterless host degrades to the CPU engines
/// and *records why* in the report — the acceptance-criteria fallback.
#[test]
fn auto_selection_records_cpu_fallback() {
    if gpu::adapter_available() {
        eprintln!(
            "SKIP auto_selection_records_cpu_fallback: \
             a device adapter is available, auto selects the gpu engine here"
        );
        return;
    }
    let (tree, table) = problem(12, 0.3, 67);
    let opts = ComputeOptions { metric: Metric::WeightedNormalized, ..Default::default() };
    let (_, rep) = compute_unifrac_report::<f64>(&tree, &table, &opts).unwrap();
    assert_ne!(rep.engine, "gpu", "auto must not pick gpu with no adapter");
    assert!(
        rep.gpu_fallback.contains("no adapter"),
        "fallback reason must be recorded, got {:?}",
        rep.gpu_fallback
    );
    assert!(rep.gpu_adapter.is_empty());
    assert_eq!(rep.gpu_dispatches, 0);

    // the same record surfaces through the public job facade
    let out = UniFracJob::with_spec(&tree, &table, JobSpec::default()).run_output().unwrap();
    assert!(out.metrics.gpu_fallback.contains("no adapter"));
    assert!(!out.metrics.backend.starts_with("gpu/"), "backend {:?}", out.metrics.backend);
}

/// Explicit vdev runs are labeled as device runs end-to-end: the report
/// carries the adapter name, the dispatch counters, and the staged-byte
/// accounting; the job facade labels the backend `gpu/vdev`.
#[test]
fn vdev_run_reports_device_accounting() {
    let (tree, table) = problem(16, 0.2, 71);
    let (_, rep) =
        compute_unifrac_report::<f64>(&tree, &table, &vdev_opts(Metric::Unweighted)).unwrap();
    assert_eq!(rep.engine, "gpu");
    assert_eq!(rep.gpu_adapter, gpu::VDEV_ADAPTER);
    assert!(rep.gpu_fallback.is_empty());
    assert!(rep.gpu_dispatches > 0, "device runs must count dispatches");
    assert!(rep.gpu_bytes_staged > 0, "device runs must count staged bytes");

    let spec = JobSpec {
        engine: Some(EngineKind::Gpu),
        gpu_adapter: "vdev".into(),
        ..Default::default()
    };
    let out = UniFracJob::with_spec(&tree, &table, spec).run_output().unwrap();
    assert_eq!(out.metrics.backend, "gpu/vdev");
    assert_eq!(out.metrics.gpu_adapter, "vdev");
    assert!(out.metrics.gpu_dispatches > 0);
}

/// Requesting a *named* adapter that does not exist is the same typed
/// rejection (on a host with an adapter the message names the mismatch;
/// on an adapterless host it routes to the virtual device).
#[test]
fn named_adapter_mismatch_is_typed_unsupported() {
    let err = gpu::resolve_adapter("no-such-silicon").unwrap_err();
    assert!(matches!(err, Error::Unsupported(_)), "got {err:?}");
}

/// Real-adapter conformance: the physical device must agree with the
/// virtual device under the same tolerance contracts. `#[ignore]`-gated
/// — run with `cargo test -- --ignored` on a GPU host; prints a visible
/// notice (not a silent pass) when no adapter exists.
#[test]
#[ignore = "requires a physical GPU adapter; run with --ignored on a device host"]
fn real_adapter_matches_vdev() {
    let Some(adapter) = gpu::host::probe() else {
        eprintln!(
            "SKIP real_adapter_matches_vdev: no GPU adapter detected on this host \
             (the vdev conformance suite above still covers the kernel plan)"
        );
        return;
    };
    let (tree, table) = problem(24, 0.2, 73);
    let vdev = compute_unifrac::<f64>(&tree, &table, &vdev_opts(Metric::WeightedNormalized))
        .unwrap();
    let real = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions {
            gpu_adapter: "auto".to_string(),
            ..vdev_opts(Metric::WeightedNormalized)
        },
    )
    .unwrap();
    let d64 = real.max_abs_diff(&vdev);
    assert!(d64 < 1e-12, "adapter {}: f64 divergence {d64:e}", adapter.name);

    let real32 = compute_unifrac::<f32>(&tree, &table, &vdev_opts(Metric::WeightedNormalized))
        .unwrap();
    let d32 = real32.max_abs_diff(&vdev);
    assert!(d32 < GPU_F32_TOLERANCE, "adapter {}: f32 divergence {d32:e}", adapter.name);
}
