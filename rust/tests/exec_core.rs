//! Property tests for the unified streaming execution core (ISSUE 1):
//! scheduling strategy, batch pooling and thread count must never change
//! results, across odd/even sample counts and all four metrics, with
//! `compute_unifrac_naive` as the oracle.

use unifrac::coordinator::{run, RunOptions};
use unifrac::exec::SchedulerKind;
use unifrac::synth::SynthSpec;
use unifrac::unifrac::{
    compute_unifrac, compute_unifrac_naive, compute_unifrac_report, ComputeOptions, Metric,
};

fn workload(n: usize, seed: u64) -> (unifrac::tree::Phylogeny, unifrac::table::FeatureTable) {
    SynthSpec { n_samples: n, n_features: 128, density: 0.08, seed, ..Default::default() }
        .generate()
}

#[test]
fn schedulers_and_pooling_match_naive_oracle() {
    for n in [21usize, 24] {
        let (tree, table) = workload(n, 7);
        for metric in Metric::all(0.5) {
            let oracle = compute_unifrac_naive(&tree, &table, metric).unwrap();
            for scheduler in [SchedulerKind::Static, SchedulerKind::Dynamic] {
                for pool_depth in [0usize, 8] {
                    for threads in [1usize, 2, 3, 8] {
                        let opts = ComputeOptions {
                            metric,
                            threads,
                            scheduler,
                            pool_depth,
                            batch_capacity: 6,
                            block_k: 8,
                            ..Default::default()
                        };
                        let dm = compute_unifrac::<f64>(&tree, &table, &opts).unwrap();
                        let diff = dm.max_abs_diff(&oracle);
                        assert!(
                            diff < 1e-10,
                            "n={n} {metric} {scheduler:?} pool={pool_depth} \
                             threads={threads}: diff {diff}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn pooled_and_unpooled_are_bit_identical() {
    // pooling only changes buffer reuse, never fold order: results must
    // match bit-for-bit, not just within tolerance
    for threads in [1usize, 3] {
        let (tree, table) = workload(22, 11);
        let base = ComputeOptions { threads, batch_capacity: 5, ..Default::default() };
        let pooled = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { pool_depth: 8, ..base.clone() },
        )
        .unwrap();
        let unpooled = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { pool_depth: 0, ..base.clone() },
        )
        .unwrap();
        assert_eq!(pooled.condensed(), unpooled.condensed(), "threads={threads}");
    }
}

#[test]
fn static_scheduling_is_bit_identical_across_thread_counts() {
    // static ranges preserve per-stripe fold order exactly, so any
    // thread count reproduces the single-thread result bit-for-bit
    let (tree, table) = workload(24, 13);
    let single = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions { batch_capacity: 7, ..Default::default() },
    )
    .unwrap();
    for threads in [2usize, 3, 8] {
        let multi = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { batch_capacity: 7, threads, ..Default::default() },
        )
        .unwrap();
        assert_eq!(single.condensed(), multi.condensed(), "threads={threads}");
    }
}

#[test]
fn pool_reuse_counter_proves_zero_steady_state_allocation() {
    let (tree, table) = workload(20, 17);
    let (_, rep) = compute_unifrac_report::<f64>(
        &tree,
        &table,
        &ComputeOptions { batch_capacity: 2, ..Default::default() },
    )
    .unwrap();
    // inline streaming reuses the single buffer for every batch
    assert_eq!(rep.pool_allocated, 1);
    assert_eq!(rep.pool_reused, rep.batches);
    assert!(rep.batches > 10, "stream long enough to be meaningful");

    let (_, rep) = compute_unifrac_report::<f64>(
        &tree,
        &table,
        &ComputeOptions { batch_capacity: 2, threads: 3, ..Default::default() },
    )
    .unwrap();
    // broadcast streaming: allocation bounded by the in-flight window
    assert_eq!(rep.pool_allocated + rep.pool_reused, rep.batches + 1);
    assert!(rep.pool_allocated <= 8, "in-flight window exceeded: {}", rep.pool_allocated);
}

#[test]
fn dynamic_coordinator_run_matches_naive() {
    let (tree, table) = workload(27, 23);
    let oracle =
        compute_unifrac_naive(&tree, &table, Metric::WeightedNormalized).unwrap();
    for chips in [2usize, 4] {
        let out = run::<f64>(
            &tree,
            &table,
            &RunOptions {
                chips,
                batch_capacity: 8,
                scheduler: SchedulerKind::Dynamic,
                artifacts_dir: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(out.dm.max_abs_diff(&oracle) < 1e-10, "chips={chips}");
        assert_eq!(out.metrics.scheduler, "dynamic");
        assert!(out.metrics.pool_reused > 0);
    }
}

#[test]
fn fp32_runs_through_both_schedulers() {
    let (tree, table) = workload(18, 29);
    let d64 = compute_unifrac::<f64>(&tree, &table, &ComputeOptions::default()).unwrap();
    for scheduler in [SchedulerKind::Static, SchedulerKind::Dynamic] {
        let d32 = compute_unifrac::<f32>(
            &tree,
            &table,
            &ComputeOptions { scheduler, threads: 2, ..Default::default() },
        )
        .unwrap();
        assert!(d64.max_abs_diff(&d32) < 1e-4, "{scheduler:?}");
    }
}
