//! ReferenceSet snapshot + k-vs-N query properties (ISSUE 8).
//!
//! The two load-bearing claims:
//! 1. snapshot → serialize → load → query is **bit-identical** to
//!    querying the fresh in-memory snapshot (the UFRS round trip loses
//!    nothing), and
//! 2. the k-vs-N rectangle matches the corresponding entries of a full
//!    (N+k)-sample engine run — exactly for the tiled engine (same
//!    per-cell accumulation order), within tight tolerance for the
//!    reordered kernels.

use std::path::PathBuf;
use std::time::Instant;

use unifrac::embed::EmbeddingKind;
use unifrac::service::{query, refset, QuerySpec, ReferenceSet};
use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::unifrac::{compute_unifrac, ComputeOptions, EngineKind};
use unifrac::{Error, FpWidth, Metric};

const N_REF: usize = 24;
const K: usize = 8;

fn problem() -> (Phylogeny, FeatureTable, FeatureTable, FeatureTable) {
    let spec = SynthSpec {
        n_samples: N_REF + K,
        n_features: 256,
        density: 0.1,
        seed: 77,
        ..Default::default()
    };
    let (tree, combined) = spec.generate();
    let ref_table = combined.select_samples(&(0..N_REF).collect::<Vec<_>>()).unwrap();
    let query_table =
        combined.select_samples(&(N_REF..N_REF + K).collect::<Vec<_>>()).unwrap();
    (tree, combined, ref_table, query_table)
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("unifrac_service_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn snapshot_roundtrip_is_bit_identical() {
    let (tree, _, ref_table, query_table) = problem();
    for metric in Metric::all(1.5) {
        let fresh = ReferenceSet::snapshot(&tree, &ref_table, metric.embedding_kind()).unwrap();
        let loaded = ReferenceSet::from_bytes(&fresh.to_bytes()).unwrap();
        assert_eq!(loaded.n_samples(), N_REF);
        assert_eq!(loaded.n_rows(), fresh.n_rows());
        assert_eq!(loaded.ids(), fresh.ids());
        assert_eq!(loaded.newick(), fresh.newick());
        for fp in [FpWidth::F64, FpWidth::F32] {
            let spec = QuerySpec::new(metric, fp);
            let a = query::run(&fresh, &query_table, &spec).unwrap();
            let b = query::run(&loaded, &query_table, &spec).unwrap();
            assert_eq!(a.query_ids, b.query_ids);
            assert_eq!(a.ref_ids, b.ref_ids);
            for (x, y) in a.distances.iter().zip(&b.distances) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "round-trip must be bit-identical ({metric}, {fp:?})"
                );
            }
        }
    }
}

#[test]
fn query_matches_full_matrix_across_engines() {
    let (tree, combined, ref_table, query_table) = problem();
    for metric in Metric::all(1.5) {
        let rs = ReferenceSet::snapshot(&tree, &ref_table, metric.embedding_kind()).unwrap();
        let out = query::run(&rs, &query_table, &QuerySpec::new(metric, FpWidth::F64)).unwrap();
        let out32 = query::run(&rs, &query_table, &QuerySpec::new(metric, FpWidth::F32)).unwrap();
        for engine in EngineKind::all() {
            if !engine.supports(metric) {
                continue;
            }
            let opts = ComputeOptions { metric, engine: Some(engine), ..Default::default() };
            let dm = compute_unifrac::<f64>(&tree, &combined, &opts).unwrap();
            for q in 0..K {
                for j in 0..N_REF {
                    let want = dm.get(N_REF + q, j);
                    let got = out.get(q, j);
                    if engine == EngineKind::Tiled {
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "tiled f64 must match exactly ({metric}, q={q}, j={j})"
                        );
                    } else {
                        assert!(
                            (got - want).abs() < 1e-12,
                            "{metric}/{engine:?} q={q} j={j}: {got} vs {want}"
                        );
                    }
                    assert!(
                        (out32.get(q, j) - want).abs() < 2e-5,
                        "f32 query drifted ({metric}, q={q}, j={j})"
                    );
                }
            }
        }
    }
}

#[test]
fn save_load_and_flipped_byte_is_corrupt() {
    let (tree, _, ref_table, query_table) = problem();
    let dir = tmpdir("corrupt");
    let rs = ReferenceSet::snapshot(&tree, &ref_table, EmbeddingKind::Presence).unwrap();
    let path = dir.join("ref.ufrs");
    rs.save(&path).unwrap();
    let loaded = ReferenceSet::load(&path).unwrap();
    let spec = QuerySpec::new(Metric::Unweighted, FpWidth::F64);
    let a = query::run(&rs, &query_table, &spec).unwrap();
    let b = query::run(&loaded, &query_table, &spec).unwrap();
    assert_eq!(a.distances, b.distances);

    let bytes = std::fs::read(&path).unwrap();
    // flip one bit deep in the payload: must be Corrupt, detected from
    // the stored CRC before any payload decode
    let mut bad = bytes.clone();
    let at = bad.len() - 9;
    bad[at] ^= 0x10;
    match ReferenceSet::from_bytes(&bad) {
        Err(Error::Corrupt(m)) => assert!(m.contains("checksum"), "{m}"),
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // flip a header byte (sample id region): also Corrupt
    let mut bad = bytes.clone();
    bad[40] ^= 0x01;
    assert!(matches!(ReferenceSet::from_bytes(&bad), Err(Error::Corrupt(_))));
    // truncation: error, never a panic
    assert!(ReferenceSet::from_bytes(&bytes[..bytes.len() - 7]).is_err());
    assert!(ReferenceSet::from_bytes(&bytes[..10]).is_err());
    // the inspect helper agrees
    let c = refset::check_bytes(&bytes).unwrap();
    assert_eq!(c.n_samples, N_REF);
    assert!(c.checksums_ok);
    let mut bad = bytes.clone();
    let at = bad.len() - 1;
    bad[at] ^= 0x80;
    assert!(!refset::check_bytes(&bad).unwrap().checksums_ok);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn guard_rails() {
    let (tree, _, ref_table, query_table) = problem();
    // kind mismatch: presence snapshot cannot serve weighted metrics
    let rs = ReferenceSet::snapshot(&tree, &ref_table, EmbeddingKind::Presence).unwrap();
    let wspec = QuerySpec::new(Metric::WeightedNormalized, FpWidth::F64);
    let err = query::run(&rs, &query_table, &wspec).unwrap_err();
    assert!(matches!(err, Error::Invalid(_)), "{err}");

    // k > N is a typed refusal pointing at the full-matrix path
    let two = ref_table.select_samples(&[0, 1]).unwrap();
    let rs_small = ReferenceSet::snapshot(&tree, &two, EmbeddingKind::Presence).unwrap();
    let err =
        query::run(&rs_small, &query_table, &QuerySpec::new(Metric::Unweighted, FpWidth::F64))
            .unwrap_err();
    assert!(err.to_string().contains("full matrix"), "{err}");

    // an already-expired deadline fails typed, before finishing
    let mut spec = QuerySpec::new(Metric::Unweighted, FpWidth::F64);
    spec.deadline = Some(Instant::now());
    let err = query::run(&rs, &query_table, &spec).unwrap_err();
    assert!(matches!(err, Error::DeadlineExceeded(_)), "{err}");
    assert_eq!(err.code(), 24);

    // tiny reference sets are rejected at snapshot time
    let one = ref_table.select_samples(&[0]).unwrap();
    assert!(ReferenceSet::snapshot(&tree, &one, EmbeddingKind::Presence).is_err());
}

#[test]
fn tsv_and_json_round_trip() {
    let (tree, _, ref_table, query_table) = problem();
    let rs = ReferenceSet::snapshot(&tree, &ref_table, EmbeddingKind::Proportion).unwrap();
    let out =
        query::run(&rs, &query_table, &QuerySpec::new(Metric::WeightedNormalized, FpWidth::F64))
            .unwrap();
    // JSON transport is lossless (shortest-round-trip f64)
    let j = query::output_to_json(&out);
    let back = query::output_from_json(
        &unifrac::util::json::Json::parse(&j.dump()).unwrap(),
    )
    .unwrap();
    assert_eq!(back.query_ids, out.query_ids);
    assert_eq!(back.ref_ids, out.ref_ids);
    for (x, y) in out.distances.iter().zip(&back.distances) {
        assert_eq!(x.to_bits(), y.to_bits(), "JSON hop must be lossless");
    }
    // TSV shape: header + one row per query sample
    let mut buf = Vec::new();
    query::write_query_tsv(&mut buf, &out).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), K + 1);
    assert_eq!(lines[0].split('\t').count(), N_REF + 1);
    assert!(lines[1].starts_with(&out.query_ids[0]));
}
