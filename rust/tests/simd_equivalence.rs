//! SIMD-vs-scalar equivalence suite (ISSUE 6 satellite): the
//! auto-dispatched vector kernels must agree with the forced-scalar
//! reference path to <1e-12 — in fact bit-for-bit, since the SIMD lanes
//! preserve the scalar accumulation grouping — across every metric,
//! both precisions, a density axis, multi-batch accumulation, and the
//! tile-remainder shapes. On hosts without AVX2/NEON the auto path *is*
//! scalar and the suite degenerates to a self-comparison, which is
//! exactly the intended behavior of the fallback.

use unifrac::api::{JobSpec, UniFracJob};
use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::unifrac::{
    compute_unifrac, compute_unifrac_naive, simd, ComputeOptions, CpuFeatures, EngineKind, Metric,
};
use unifrac::Error;

fn problem(n: usize, density: f64, seed: u64) -> (Phylogeny, FeatureTable) {
    SynthSpec {
        n_samples: n,
        n_features: (n * 8).max(256),
        density,
        seed,
        ..Default::default()
    }
    .generate()
}

/// Run one compute twice — forced scalar and auto dispatch — and demand
/// bit-identical distance matrices.
fn assert_paths_agree<R: unifrac::util::Real + unifrac::runtime::XlaReal>(
    tree: &Phylogeny,
    table: &FeatureTable,
    base: &ComputeOptions,
    what: &str,
) {
    let scalar = compute_unifrac::<R>(
        tree,
        table,
        &ComputeOptions { cpu_features: CpuFeatures::Scalar, ..base.clone() },
    )
    .unwrap();
    let auto = compute_unifrac::<R>(
        tree,
        table,
        &ComputeOptions { cpu_features: CpuFeatures::Auto, ..base.clone() },
    )
    .unwrap();
    let diff = scalar.max_abs_diff(&auto);
    assert!(
        diff == 0.0,
        "{what} ({}): scalar/auto divergence {diff:e} (requirement < 1e-12, design: exact)",
        R::TAG
    );
}

/// Every metric × every supporting engine × both precisions × a density
/// axis: auto dispatch is bit-identical to forced scalar.
#[test]
fn auto_matches_scalar_across_metrics_engines_densities() {
    for &density in &[0.02, 0.2, 0.8] {
        let (tree, table) = problem(24, density, 100 + (density * 100.0) as u64);
        for metric in Metric::all(0.5) {
            for engine in EngineKind::all() {
                if !engine.supports(metric) {
                    continue;
                }
                let base = ComputeOptions {
                    metric,
                    engine: Some(engine),
                    batch_capacity: 16,
                    ..Default::default()
                };
                let what = format!("{metric} {} density={density}", engine.name());
                assert_paths_agree::<f64>(&tree, &table, &base, &what);
                assert_paths_agree::<f32>(&tree, &table, &base, &what);
            }
        }
    }
}

/// The vector kernels still produce correct *answers*, not just
/// self-consistent ones: auto dispatch matches the naive oracle.
#[test]
fn auto_matches_naive_oracle() {
    let (tree, table) = problem(18, 0.15, 7);
    for metric in Metric::all(0.5) {
        let oracle = compute_unifrac_naive(&tree, &table, metric).unwrap();
        let auto = compute_unifrac::<f64>(
            &tree,
            &table,
            &ComputeOptions { metric, ..Default::default() },
        )
        .unwrap();
        let diff = auto.max_abs_diff(&oracle);
        assert!(diff < 1e-10, "{metric}: oracle diff {diff:e}");
    }
}

/// Remainder shapes: n=33 with odd block_k values exercises both the
/// 4-lane/2-lane main loops and their scalar column tails, plus the
/// tiled row remainder.
#[test]
fn tile_remainder_shapes_agree() {
    let (tree, table) = problem(33, 0.2, 11);
    for &block_k in &[1usize, 13, 16] {
        for metric in [Metric::Unweighted, Metric::WeightedNormalized] {
            let base = ComputeOptions {
                metric,
                engine: Some(EngineKind::Tiled),
                block_k,
                batch_capacity: 8,
                ..Default::default()
            };
            let what = format!("{metric} tiled block_k={block_k}");
            assert_paths_agree::<f64>(&tree, &table, &base, &what);
            assert_paths_agree::<f32>(&tree, &table, &base, &what);
        }
    }
}

/// Multi-batch accumulation: tiny batch capacities force many partial
/// folds into the same stripe scratch; the order-preserving lanes must
/// keep the result bit-identical to scalar.
#[test]
fn multi_batch_accumulation_agrees() {
    let (tree, table) = problem(21, 0.3, 13);
    for &batch_capacity in &[1usize, 7, 64] {
        for (metric, engine) in [
            (Metric::Unweighted, EngineKind::Packed),
            (Metric::WeightedNormalized, EngineKind::Sparse),
            (Metric::WeightedUnnormalized, EngineKind::Tiled),
        ] {
            let base = ComputeOptions {
                metric,
                engine: Some(engine),
                batch_capacity,
                ..Default::default()
            };
            let what = format!("{metric} {} cap={batch_capacity}", engine.name());
            assert_paths_agree::<f64>(&tree, &table, &base, &what);
            assert_paths_agree::<f32>(&tree, &table, &base, &what);
        }
    }
}

/// Whole-pipeline check through the public facade: a multi-threaded
/// `UniFracJob` forced onto scalar equals the auto-dispatched one, and
/// the run metrics report the kernel path that actually executed.
#[test]
fn jobspec_pipeline_agrees_and_reports_path() {
    let (tree, table) = problem(40, 0.1, 17);
    let spec = |cpu: CpuFeatures| JobSpec {
        metric: Metric::WeightedNormalized,
        engine: Some(EngineKind::Tiled),
        threads: 2,
        batch_capacity: 16,
        cpu_features: cpu,
        ..Default::default()
    };
    let scalar = UniFracJob::with_spec(&tree, &table, spec(CpuFeatures::Scalar))
        .run_output()
        .unwrap();
    let auto = UniFracJob::with_spec(&tree, &table, spec(CpuFeatures::Auto))
        .run_output()
        .unwrap();
    let diff = scalar.dm.max_abs_diff(&auto.dm);
    assert!(diff == 0.0, "pipeline scalar/auto divergence {diff:e}");
    assert_eq!(scalar.metrics.kernel_path, "scalar");
    let expected =
        simd::tile_effective::<f64>(simd::auto_path(), Metric::WeightedNormalized).name();
    assert_eq!(auto.metrics.kernel_path, expected);
}

/// Requesting an ISA this host does not have is a typed
/// `Error::Unsupported` at construction, not a silent downgrade.
#[test]
fn unavailable_isa_is_rejected() {
    let (tree, table) = problem(10, 0.2, 19);
    #[cfg(target_arch = "x86_64")]
    let foreign = CpuFeatures::Neon;
    #[cfg(not(target_arch = "x86_64"))]
    let foreign = CpuFeatures::Avx2;
    let err = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions { cpu_features: foreign, ..Default::default() },
    )
    .unwrap_err();
    assert!(matches!(err, Error::Unsupported(_)), "got {err:?}");
}

/// An explicitly requested ISA that IS available runs and matches
/// scalar — covered only where the host supports it.
#[test]
fn explicit_available_isa_agrees_with_scalar() {
    let native = match simd::best_available() {
        unifrac::unifrac::KernelPath::Avx2 => CpuFeatures::Avx2,
        unifrac::unifrac::KernelPath::Neon => CpuFeatures::Neon,
        unifrac::unifrac::KernelPath::Scalar => return, // nothing to test here
    };
    let (tree, table) = problem(16, 0.25, 23);
    let base = ComputeOptions {
        metric: Metric::WeightedNormalized,
        engine: Some(EngineKind::Tiled),
        ..Default::default()
    };
    let scalar = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions { cpu_features: CpuFeatures::Scalar, ..base.clone() },
    )
    .unwrap();
    let explicit = compute_unifrac::<f64>(
        &tree,
        &table,
        &ComputeOptions { cpu_features: native, ..base },
    )
    .unwrap();
    assert_eq!(scalar.max_abs_diff(&explicit), 0.0);
}
