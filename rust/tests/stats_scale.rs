//! ISSUE 9 accuracy contracts for the large-N stats path (`stats::scale`):
//!
//! * the randomized range-finder PCoA reproduces the exact dense Jacobi
//!   solver when the sketch covers the full spectrum (Procrustes RMS
//!   < 1e-6, eigenvalues to 1e-9), on a *disk-backed* UFDM file — the
//!   solver's only matrix access is the `CondensedView` pair stream;
//! * its working set is O(n·ℓ), not O(n²) — asserted against the
//!   measured `peak_resident_bytes`;
//! * `load_view` sniffs the matrix format from the first bytes, and the
//!   streamed (mmap) path is bitwise identical to an in-memory copy of
//!   the same distances (the regression test for the pcoa/permanova CLI
//!   verbs growing binary-matrix input);
//! * batched PERMANOVA is bitwise invariant across `--perm-batch`
//!   widths, including on the disk-backed view.

use std::path::PathBuf;
use unifrac::matrix::{load_view, CondensedFile, CondensedMatrix, OutputFormat};
use unifrac::stats::{
    pcoa_exact_dense, pcoa_scale, permanova_with, procrustes_rms, PcoaOpts, PermanovaOpts,
};
use unifrac::synth::SynthSpec;
use unifrac::util::Xoshiro256;
use unifrac::{Metric, UniFracJob};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("unifrac_stats_scale").join(name);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Compute a real UniFrac distance matrix (Emd metric — the new family)
/// and persist it as a binary UFDM file; the tests stream it from disk.
fn disk_matrix(dir: &PathBuf, n_samples: usize) -> PathBuf {
    let (tree, table) =
        SynthSpec { n_samples, n_features: 192, density: 0.1, ..Default::default() }.generate();
    let path = dir.join(format!("dm_{n_samples}.ufdm"));
    UniFracJob::new(&tree, &table)
        .metric(Metric::Emd)
        .output_format(OutputFormat::Mmap)
        .run_to_path(&path)
        .unwrap();
    path
}

/// Random-point euclidean distances: rank(Gower) ≤ dims, handy for
/// memory-contract runs that don't need a UniFrac compute first.
fn euclidean_matrix(n: usize, dims: usize, seed: u64) -> CondensedMatrix {
    let mut rng = Xoshiro256::new(seed);
    let pts: Vec<Vec<f64>> = (0..n).map(|_| (0..dims).map(|_| rng.f64()).collect()).collect();
    let mut dm = CondensedMatrix::zeros(n, vec![]);
    for i in 0..n {
        for j in (i + 1)..n {
            let d = pts[i]
                .iter()
                .zip(&pts[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            dm.set(i, j, d);
        }
    }
    dm
}

/// Full-rank sketch (ℓ = n) on a disk-backed UFDM: the randomized
/// solver must reproduce the exact dense Jacobi reference.
#[test]
fn randomized_pcoa_matches_exact_dense_at_full_rank() {
    let dir = tmpdir("fullrank");
    let n = 96;
    let path = disk_matrix(&dir, n);
    let f = CondensedFile::open(&path).unwrap();

    let k = 6;
    let opts = PcoaOpts { components: k, oversample: n, power_iters: 2, seed: 3 };
    let (fast, stats) = pcoa_scale(&f, &opts);
    let dense = pcoa_exact_dense(&f, k);

    assert_eq!(stats.sketch_columns, n, "oversample >= n must clamp to a full-rank sketch");
    assert_eq!(fast.eigenvalues.len(), k);
    assert_eq!(fast.coordinates.len(), k);

    let scale = dense.eigenvalues[0].abs().max(1.0);
    for (i, (a, b)) in fast.eigenvalues.iter().zip(&dense.eigenvalues).enumerate() {
        assert!(
            (a - b).abs() <= 1e-9 * scale,
            "eigenvalue {i}: randomized {a} vs dense {b}"
        );
    }
    let rms = procrustes_rms(&dense.coordinates, &fast.coordinates);
    assert!(rms < 1e-6, "procrustes rms {rms:e} exceeds 1e-6 at full rank");
}

/// The solver's working set is O(n·ℓ): with a small sketch at n = 256,
/// `peak_resident_bytes` stays within 2× the panel-accounting formula
/// and well under the dense Gower matrix (8·n²).
#[test]
fn peak_resident_bytes_is_linear_in_n_times_sketch() {
    let n = 256;
    let dm = euclidean_matrix(n, 6, 11);
    let opts = PcoaOpts { components: 8, oversample: 8, power_iters: 2, seed: 5 };
    let (res, stats) = pcoa_scale(&dm, &opts);
    assert_eq!(res.coordinates.len(), 8);

    let l = stats.sketch_columns;
    assert_eq!(l, 16);
    let formula = 8 * (3 * n * l + 3 * l * l + opts.components * n + l);
    assert!(
        stats.peak_resident_bytes <= 2 * formula,
        "peak {} exceeds 2x the O(n*l) accounting bound {}",
        stats.peak_resident_bytes,
        2 * formula
    );
    let dense_bytes = 8 * n * n;
    assert!(
        stats.peak_resident_bytes < dense_bytes / 2,
        "peak {} is not materially below the dense Gower {}",
        stats.peak_resident_bytes,
        dense_bytes
    );
    assert_eq!(stats.matrix_passes, opts.power_iters + 2);
}

/// `load_view` sniffs UFDM magic vs TSV from the first bytes; the
/// streamed mmap view feeds the solver bitwise identically to an
/// in-memory copy of the same distances.
#[test]
fn load_view_sniffs_format_and_streams_bitwise_identically() {
    let dir = tmpdir("sniff");
    let path = disk_matrix(&dir, 40);
    let f = CondensedFile::open(&path).unwrap();
    let mem = f.to_matrix();

    // sniffed binary view == direct open, and the TSV branch parses too
    let via_sniff = load_view(&path).unwrap();
    assert_eq!(via_sniff.n_samples(), 40);
    let tsv = dir.join("dm.tsv");
    f.write_tsv(&tsv).unwrap();
    let via_tsv = load_view(&tsv).unwrap();
    assert_eq!(via_tsv.n_samples(), 40);
    // TSV cells are quantized at 1e-10 by the shared formatter; the
    // parsed matrix must agree with the binary to that precision.
    let mut max_diff = 0.0f64;
    for i in 0..40 {
        for j in 0..40 {
            max_diff = max_diff.max((via_tsv.get(i, j) - mem.get(i, j)).abs());
        }
    }
    assert!(max_diff <= 5e-10, "tsv round-trip drifted by {max_diff:e}");

    // bitwise contract: disk-streamed == in-memory on identical bytes
    let opts = PcoaOpts { components: 5, oversample: 8, power_iters: 2, seed: 9 };
    let (from_disk, _) = pcoa_scale(&*via_sniff, &opts);
    let (from_mem, _) = pcoa_scale(&mem, &opts);
    assert_eq!(from_disk.eigenvalues.len(), from_mem.eigenvalues.len());
    for (a, b) in from_disk.eigenvalues.iter().zip(&from_mem.eigenvalues) {
        assert_eq!(a.to_bits(), b.to_bits(), "eigenvalues must be bitwise identical");
    }
    for (ax_d, ax_m) in from_disk.coordinates.iter().zip(&from_mem.coordinates) {
        for (a, b) in ax_d.iter().zip(ax_m) {
            assert_eq!(a.to_bits(), b.to_bits(), "coordinates must be bitwise identical");
        }
    }
}

/// PERMANOVA results are bitwise independent of the permutation batch
/// width — on the disk-backed view and its in-memory copy alike.
#[test]
fn permanova_is_bitwise_invariant_across_batch_widths() {
    let dir = tmpdir("permanova");
    let path = disk_matrix(&dir, 40);
    let f = CondensedFile::open(&path).unwrap();
    let mem = f.to_matrix();
    let groups: Vec<usize> = (0..40).map(|i| i % 3).collect();

    let run = |batch: usize| {
        permanova_with(&f, &groups, &PermanovaOpts { permutations: 99, batch, seed: 17 })
    };
    let want = run(32);
    assert!(want.pseudo_f.is_finite());
    assert!((0.0..=1.0).contains(&want.p_value));
    for batch in [1, 2, 7, 99, 1000] {
        let got = run(batch);
        assert_eq!(
            got.pseudo_f.to_bits(),
            want.pseudo_f.to_bits(),
            "pseudo-F differs at batch={batch}"
        );
        assert_eq!(got.p_value.to_bits(), want.p_value.to_bits(), "p differs at batch={batch}");
        assert_eq!(got.permutations, want.permutations);
        assert_eq!(got.n_groups, 3);
    }
    let in_mem =
        permanova_with(&mem, &groups, &PermanovaOpts { permutations: 99, batch: 32, seed: 17 });
    assert_eq!(in_mem.pseudo_f.to_bits(), want.pseudo_f.to_bits());
    assert_eq!(in_mem.p_value.to_bits(), want.p_value.to_bits());
}
