//! Property suite for first-class partial computation (ISSUE 4): any
//! partition of the stripe space into `run_partial` ranges — singleton,
//! uneven, halves — merges **bit-identically** (max abs diff == 0) to
//! the full `UniFracJob::run` result, across engines × metrics ×
//! f32/f64; plus the error paths (gap / overlap / metadata mismatch)
//! and the PartialResult serialization round-trip.

use unifrac::api::{merge_partials, FpWidth, PartialResult, UniFracJob};
use unifrac::error::{Error, MergeError};
use unifrac::matrix::CondensedMatrix;
use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::unifrac::{EngineKind, Metric};

fn problem(n: usize, seed: u64) -> (Phylogeny, FeatureTable) {
    SynthSpec { n_samples: n, n_features: 128, density: 0.1, seed, ..Default::default() }
        .generate()
}

/// A representative set of partitions of `0..total`: one piece, halves,
/// all singletons, and an uneven three-way split.
fn partitions(total: usize) -> Vec<Vec<(usize, usize)>> {
    let mut out = vec![vec![(0, total)]];
    if total >= 2 {
        let h = total / 2;
        out.push(vec![(0, h), (h, total - h)]);
        out.push((0..total).map(|s| (s, 1)).collect());
    }
    if total >= 4 {
        // uneven: a singleton, a big middle, a small tail — in shuffled
        // order to prove merge does not require sorted inputs
        out.push(vec![(total - 2, 2), (0, 1), (1, total - 3)]);
    }
    out
}

fn assert_partitions_exact(job: &UniFracJob<'_>, full: &CondensedMatrix, label: &str) {
    let total = job.total_stripes().unwrap();
    for cuts in partitions(total) {
        let parts: Vec<PartialResult> = cuts
            .iter()
            .map(|&(s, c)| {
                job.run_partial_range(s, c)
                    .unwrap_or_else(|e| panic!("{label}: partial ({s},{c}): {e}"))
            })
            .collect();
        let merged = merge_partials(&parts)
            .unwrap_or_else(|e| panic!("{label}: merge {cuts:?}: {e}"));
        let diff = merged.max_abs_diff(full);
        assert_eq!(diff, 0.0, "{label}: partition {cuts:?} not bit-identical ({diff:e})");
    }
}

#[test]
fn every_partition_merges_bit_identical_all_engines_metrics_precisions() {
    let (tree, table) = problem(21, 7);
    for metric in Metric::all(0.5) {
        for engine in EngineKind::ALL {
            if !engine.supports(metric) {
                continue;
            }
            for fp in [FpWidth::F64, FpWidth::F32] {
                let job = UniFracJob::new(&tree, &table)
                    .metric(metric)
                    .engine(engine)
                    .precision(fp)
                    .block_k(8)
                    .batch_capacity(5);
                let full = job.run().unwrap();
                assert_partitions_exact(
                    &job,
                    &full,
                    &format!("{metric}/{}/{}", engine.name(), fp.name()),
                );
            }
        }
    }
}

#[test]
fn auto_engine_partials_follow_the_full_run() {
    // auto selection (density walk) must resolve identically for the
    // full run and every partial, or padding/engine would diverge
    let (tree, table) = problem(20, 11);
    for metric in [Metric::Unweighted, Metric::WeightedNormalized] {
        let job = UniFracJob::new(&tree, &table).metric(metric);
        let full = job.run().unwrap();
        assert_partitions_exact(&job, &full, &format!("auto/{metric}"));
    }
}

#[test]
fn multithreaded_partials_match_multithreaded_full_run() {
    let (tree, table) = problem(26, 3);
    for metric in [Metric::Unweighted, Metric::WeightedNormalized] {
        let job = UniFracJob::new(&tree, &table).metric(metric).threads(3);
        let full = job.run().unwrap();
        let total = job.total_stripes().unwrap();
        let h = total / 2;
        let parts = vec![
            job.run_partial_range(0, h).unwrap(),
            job.run_partial_range(h, total - h).unwrap(),
        ];
        let merged = merge_partials(&parts).unwrap();
        assert_eq!(merged.max_abs_diff(&full), 0.0, "{metric} threads=3");
    }
}

#[test]
fn mixed_engine_partials_merge_within_tolerance() {
    // heterogeneous fleets: one range on the tiled stage, the rest on
    // batched — allowed by design, equal to within scalar agreement
    let (tree, table) = problem(18, 5);
    // block_k 4 keeps the tiled padding quantum equal to the scalar
    // engines' base quantum, so both jobs agree on the padded width
    let tiled = UniFracJob::new(&tree, &table).engine(EngineKind::Tiled).block_k(4);
    let batched = UniFracJob::new(&tree, &table).engine(EngineKind::Batched).block_k(4);
    let total = tiled.total_stripes().unwrap();
    assert_eq!(total, batched.total_stripes().unwrap(), "padding must agree");
    let h = total / 2;
    let parts = vec![
        tiled.run_partial_range(0, h).unwrap(),
        batched.run_partial_range(h, total - h).unwrap(),
    ];
    let merged = merge_partials(&parts).unwrap();
    let full = tiled.run().unwrap();
    assert!(merged.max_abs_diff(&full) < 1e-12);
}

#[test]
fn gap_overlap_and_metadata_mismatch_rejected() {
    let (tree, table) = problem(20, 9);
    let job = UniFracJob::new(&tree, &table).engine(EngineKind::Tiled).block_k(8);
    let total = job.total_stripes().unwrap();
    assert!(total >= 4, "test needs a few stripes, got {total}");

    // gap: stripe 2 missing
    let parts = vec![
        job.run_partial_range(0, 2).unwrap(),
        job.run_partial_range(3, total - 3).unwrap(),
    ];
    let err = merge_partials(&parts).expect_err("gap must be rejected");
    assert!(matches!(err, Error::Merge(MergeError::Gap { stripe: 2 })), "got {err:?}");

    // overlap: stripe 1 covered twice
    let parts = vec![
        job.run_partial_range(0, 2).unwrap(),
        job.run_partial_range(1, total - 1).unwrap(),
    ];
    let err = merge_partials(&parts).expect_err("overlap must be rejected");
    assert!(matches!(err, Error::Merge(MergeError::Overlap { .. })), "got {err:?}");

    // metric mismatch
    let other = UniFracJob::new(&tree, &table)
        .metric(Metric::WeightedUnnormalized)
        .engine(EngineKind::Tiled)
        .block_k(8);
    let parts = vec![
        job.run_partial_range(0, 2).unwrap(),
        other.run_partial_range(2, total - 2).unwrap(),
    ];
    let err = merge_partials(&parts).expect_err("metric mismatch must be rejected");
    assert!(matches!(err, Error::Merge(MergeError::MetricMismatch { .. })), "got {err:?}");

    // precision mismatch
    let f32_job = UniFracJob::new(&tree, &table)
        .engine(EngineKind::Tiled)
        .block_k(8)
        .precision(FpWidth::F32);
    let parts = vec![
        job.run_partial_range(0, 2).unwrap(),
        f32_job.run_partial_range(2, total - 2).unwrap(),
    ];
    let err = merge_partials(&parts).expect_err("precision mismatch must be rejected");
    assert!(
        matches!(err, Error::Merge(MergeError::PrecisionMismatch { .. })),
        "got {err:?}"
    );

    // different problem shape entirely
    let (tree2, table2) = problem(24, 9);
    let other_problem =
        UniFracJob::new(&tree2, &table2).engine(EngineKind::Tiled).block_k(8);
    let total2 = other_problem.total_stripes().unwrap();
    let parts = vec![
        job.run_partial_range(0, total).unwrap(),
        other_problem.run_partial_range(0, total2).unwrap(),
    ];
    let err = merge_partials(&parts).expect_err("shape mismatch must be rejected");
    assert!(
        matches!(
            err,
            Error::Merge(MergeError::SampleMismatch { .. })
                | Error::Merge(MergeError::WidthMismatch { .. })
        ),
        "got {err:?}"
    );
}

#[test]
fn serialization_roundtrip_preserves_bit_identity() {
    let dir = std::env::temp_dir().join("unifrac_partial_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let (tree, table) = problem(20, 13);
    for fp in [FpWidth::F64, FpWidth::F32] {
        let job = UniFracJob::new(&tree, &table).precision(fp);
        let full = job.run().unwrap();
        let total = job.total_stripes().unwrap();
        let h = total / 2;
        let mut loaded = Vec::new();
        for (i, (s, c)) in [(0, h), (h, total - h)].into_iter().enumerate() {
            let p = job.run_partial_range(s, c).unwrap();
            let path = dir.join(format!("p{}_{}.bin", fp.name(), i));
            p.save(&path).unwrap();
            let back = PartialResult::load(&path).unwrap();
            assert_eq!(back.meta(), p.meta(), "{} meta round-trip", fp.name());
            loaded.push(back);
        }
        let merged = merge_partials(&loaded).unwrap();
        assert_eq!(
            merged.max_abs_diff(&full),
            0.0,
            "{}: disk round-trip must stay bit-identical",
            fp.name()
        );
        // the ids survive too
        assert_eq!(merged.ids(), full.ids());
    }
}

#[test]
fn partial_metadata_is_self_describing() {
    let (tree, table) = problem(20, 17);
    let job = UniFracJob::new(&tree, &table).metric(Metric::Generalized(0.25));
    let total = job.total_stripes().unwrap();
    let p = job.run_partial_range(1, 3).unwrap();
    let m = p.meta();
    assert_eq!(m.n_samples, 20);
    assert!(m.padded_n >= 20);
    assert_eq!(m.stripe_start, 1);
    assert_eq!(m.stripe_count, 3);
    assert_eq!(m.metric, Metric::Generalized(0.25));
    assert_eq!(m.fp, FpWidth::F64);
    assert!(!m.engine.is_empty());
    assert_eq!(m.sample_ids.len(), 20);
    assert_eq!(p.stripe_range(), 1..4);
    assert!(total >= 4);
}
