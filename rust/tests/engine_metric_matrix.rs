//! Engine × metric exhaustiveness matrix (ISSUE 10 satellite).
//!
//! Every `(EngineKind, Metric)` pair either computes correct distances
//! (checked against the naive oracle) or fails with a *typed*
//! `Error::Unsupported` — never a panic, never a silently wrong answer.
//! The engine arm is an **exhaustive `match` with no wildcard**, so the
//! compiler forces this suite to take a position on every engine added
//! in the future; the metric list comes from `Metric::all`, the single
//! source the CLI and config layers also derive from.

use unifrac::synth::SynthSpec;
use unifrac::table::FeatureTable;
use unifrac::tree::Phylogeny;
use unifrac::unifrac::{
    compute_unifrac, compute_unifrac_naive, ComputeOptions, EngineKind, Metric,
};
use unifrac::Error;

fn problem() -> (Phylogeny, FeatureTable) {
    SynthSpec { n_samples: 20, n_features: 256, density: 0.25, seed: 404, ..Default::default() }
        .generate()
}

/// What the matrix expects of one cell.
enum Cell {
    /// Engine computes the metric; output must match the oracle.
    Computes,
    /// Engine rejects the metric with `Error::Unsupported`.
    Unsupported,
}

/// The support table, stated *independently* of `EngineKind::supports`
/// so a regression in that method cannot hide from this suite. The
/// match is exhaustive on purpose: adding an engine without extending
/// this test is a compile error.
fn expected(engine: EngineKind, metric: Metric) -> Cell {
    match engine {
        EngineKind::Original => Cell::Computes,
        EngineKind::Unified => Cell::Computes,
        EngineKind::Batched => Cell::Computes,
        EngineKind::Tiled => Cell::Computes,
        EngineKind::Packed => {
            if metric == Metric::Unweighted {
                Cell::Computes
            } else {
                Cell::Unsupported
            }
        }
        EngineKind::Sparse => {
            if metric == Metric::Unweighted {
                Cell::Unsupported
            } else {
                Cell::Computes
            }
        }
        // every metric; availability is the adapter's problem, and the
        // vdev adapter below makes these cells runnable on any host
        EngineKind::Gpu => Cell::Computes,
    }
}

#[test]
fn every_engine_metric_pair_computes_or_is_typed_unsupported() {
    let (tree, table) = problem();
    for metric in Metric::all(0.5) {
        let oracle = compute_unifrac_naive(&tree, &table, metric).unwrap();
        for engine in EngineKind::ALL {
            let opts = ComputeOptions {
                metric,
                engine: Some(engine),
                // always-accepted virtual device, so the gpu cells run
                // (and the CPU cells ignore the field) on adapterless CI
                gpu_adapter: "vdev".to_string(),
                ..Default::default()
            };
            let label = format!("{} × {metric}", engine.name());
            match (expected(engine, metric), compute_unifrac::<f64>(&tree, &table, &opts)) {
                (Cell::Computes, Ok(dm)) => {
                    let diff = dm.max_abs_diff(&oracle);
                    assert!(diff < 1e-10, "{label}: oracle diff {diff:e}");
                }
                (Cell::Computes, Err(e)) => panic!("{label}: expected a result, got {e:?}"),
                (Cell::Unsupported, Err(e)) => {
                    assert!(
                        matches!(e, Error::Unsupported(_)),
                        "{label}: expected Error::Unsupported, got {e:?}"
                    );
                }
                (Cell::Unsupported, Ok(_)) => {
                    panic!("{label}: engine claims support it must not have")
                }
            }
        }
    }
}

/// The independently-stated table above and the production
/// `EngineKind::supports` gate must agree cell-for-cell (the gpu rows
/// agree because `supports` is metric-only; adapter gating happens at
/// selection, which the matrix test exercises through the vdev adapter).
#[test]
fn support_table_matches_engine_declarations() {
    for metric in Metric::all(0.5) {
        for engine in EngineKind::ALL {
            let declared = engine.supports(metric);
            let tabled = matches!(expected(engine, metric), Cell::Computes);
            assert_eq!(
                declared,
                tabled,
                "{} × {metric}: supports() = {declared}, matrix table = {tabled}",
                engine.name()
            );
        }
    }
}
