//! End-to-end integration: CLI-level flows, file IO round trips,
//! fp32-vs-fp64 statistical equivalence (paper §4) at test scale.

use unifrac::matrix::CondensedMatrix;
use unifrac::stats::mantel;
use unifrac::synth::SynthSpec;
use unifrac::table::{read_table_tsv, write_table_tsv};
use unifrac::tree::{parse_newick, write_newick};
use unifrac::unifrac::{compute_unifrac, ComputeOptions, Metric};

#[test]
fn file_roundtrip_preserves_distances() {
    let dir = std::env::temp_dir().join("unifrac_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let (tree, table) =
        SynthSpec { n_samples: 18, n_features: 96, density: 0.1, ..Default::default() }
            .generate();

    let table_path = dir.join("t.tsv");
    let tree_path = dir.join("t.nwk");
    write_table_tsv(&table, &table_path).unwrap();
    std::fs::write(&tree_path, write_newick(&tree)).unwrap();

    let table2 = read_table_tsv(&table_path).unwrap();
    let tree2 = parse_newick(&std::fs::read_to_string(&tree_path).unwrap()).unwrap();

    let opts = ComputeOptions::default();
    let a = compute_unifrac::<f64>(&tree, &table, &opts).unwrap();
    let b = compute_unifrac::<f64>(&tree2, &table2, &opts).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-10);

    // distance matrix TSV round trip
    let dm_path = dir.join("dm.tsv");
    a.write_tsv(&dm_path).unwrap();
    let back = CondensedMatrix::read_tsv(&dm_path).unwrap();
    assert!(a.max_abs_diff(&back) < 1e-8);
    assert_eq!(back.ids(), table.sample_ids());
}

#[test]
fn fp32_statistically_identical_high_dynamic_range() {
    // the paper's §4 claim at test scale, with stressed dynamic range
    let spec = SynthSpec {
        n_samples: 64,
        n_features: 512,
        density: 0.02,
        lognormal_sigma: 3.5,
        ..Default::default()
    };
    let (tree, table) = spec.generate();
    for metric in [Metric::Unweighted, Metric::WeightedNormalized] {
        let opts = ComputeOptions { metric, ..Default::default() };
        let d64 = compute_unifrac::<f64>(&tree, &table, &opts).unwrap();
        let d32 = compute_unifrac::<f32>(&tree, &table, &opts).unwrap();
        let res = mantel(&d64, &d32, 199, 3);
        assert!(res.r2 > 0.99999, "{metric}: R^2 = {}", res.r2);
        assert!(res.p_value < 0.01, "{metric}: p = {}", res.p_value);
    }
}

#[test]
fn cli_binary_smoke() {
    // exercise the built binary if present (skip otherwise)
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target");
    let exe = ["release", "debug"]
        .iter()
        .map(|d| root.join(d).join("unifrac"))
        .find(|p| p.exists());
    let Some(exe) = exe else {
        eprintln!("skipping: binary not built");
        return;
    };
    let out = std::process::Command::new(&exe)
        .args(["compute", "--samples", "24", "--metric", "unweighted"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("computed unweighted"), "{stdout}");

    let out = std::process::Command::new(&exe).args(["devices"]).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("Tesla V100"));

    let out = std::process::Command::new(&exe).args(["help"]).output().unwrap();
    assert!(String::from_utf8_lossy(&out.stdout).contains("SUBCOMMANDS"));

    // unknown flags are rejected
    let out = std::process::Command::new(&exe)
        .args(["compute", "--samples", "8", "--bogus", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn cli_ordination_flows() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target");
    let exe = ["release", "debug"]
        .iter()
        .map(|d| root.join(d).join("unifrac"))
        .find(|p| p.exists());
    let Some(exe) = exe else {
        eprintln!("skipping: binary not built");
        return;
    };
    let dir = std::env::temp_dir().join("unifrac_cli_ord");
    std::fs::create_dir_all(&dir).unwrap();
    let dm_path = dir.join("dm.tsv");

    // produce a matrix via the compute flow
    let out = std::process::Command::new(&exe)
        .args([
            "compute",
            "--samples",
            "24",
            "--output",
            dm_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // pcoa over it
    let coords = dir.join("coords.tsv");
    let out = std::process::Command::new(&exe)
        .args([
            "pcoa",
            "--matrix",
            dm_path.to_str().unwrap(),
            "--axes",
            "2",
            "--output",
            coords.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let coord_text = std::fs::read_to_string(&coords).unwrap();
    assert!(coord_text.lines().count() >= 25); // header + 24 samples

    // permanova with a synthetic grouping
    let groups = dir.join("groups.tsv");
    let mut body = String::new();
    for i in 0..24 {
        body.push_str(&format!("S{i}\tg{}\n", i % 2));
    }
    std::fs::write(&groups, body).unwrap();
    let out = std::process::Command::new(&exe)
        .args([
            "permanova",
            "--matrix",
            dm_path.to_str().unwrap(),
            "--groups",
            groups.to_str().unwrap(),
            "--permutations",
            "99",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("pseudo-F"));
}

#[test]
fn cli_partial_merge_flow_matches_compute() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target");
    let exe = ["release", "debug"]
        .iter()
        .map(|d| root.join(d).join("unifrac"))
        .find(|p| p.exists());
    let Some(exe) = exe else {
        eprintln!("skipping: binary not built");
        return;
    };
    let dir = std::env::temp_dir().join("unifrac_cli_partial");
    std::fs::create_dir_all(&dir).unwrap();
    let table = dir.join("t.tsv");
    let tree = dir.join("t.nwk");
    let run = |args: &[&str]| {
        let out = std::process::Command::new(&exe).args(args).output().unwrap();
        assert!(
            out.status.success(),
            "{args:?}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };

    run(&[
        "synth",
        "--samples",
        "20",
        "--features",
        "160",
        "--out-table",
        table.to_str().unwrap(),
        "--out-tree",
        tree.to_str().unwrap(),
    ]);

    // reference: single-process compute
    let dm_ref = dir.join("dm_ref.tsv");
    run(&[
        "compute",
        "--table",
        table.to_str().unwrap(),
        "--tree",
        tree.to_str().unwrap(),
        "--output",
        dm_ref.to_str().unwrap(),
    ]);

    // the same job as three persisted partials + a merge
    let mut inputs = Vec::new();
    for i in 0..3 {
        let p = dir.join(format!("p{i}.bin"));
        let stdout = run(&[
            "partial",
            "--table",
            table.to_str().unwrap(),
            "--tree",
            tree.to_str().unwrap(),
            "--index",
            &i.to_string(),
            "--of",
            "3",
            "--out",
            p.to_str().unwrap(),
        ]);
        assert!(stdout.contains("stripes"), "{stdout}");
        inputs.push(p.to_str().unwrap().to_string());
    }
    let dm_merged = dir.join("dm_merged.tsv");
    let stdout = run(&[
        "merge",
        "--inputs",
        &inputs.join(","),
        "--output",
        dm_merged.to_str().unwrap(),
    ]);
    assert!(stdout.contains("merged 3 partials"), "{stdout}");

    // byte-identical TSVs: the merge is exact, and both handles use the
    // same formatter
    let a = std::fs::read_to_string(&dm_ref).unwrap();
    let b = std::fs::read_to_string(&dm_merged).unwrap();
    assert_eq!(a, b, "merged TSV must equal the single-process TSV");

    // a gap (2 of 3 partials) must fail with the merge exit code
    let out = std::process::Command::new(&exe)
        .args(["merge", "--inputs", &inputs[..2].join(",")])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(21), "merge errors exit with code 21");
}
