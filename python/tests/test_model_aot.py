"""Layer-2 + AOT path tests: engines agree, lowering round-trips, manifest
is complete and self-consistent."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels.ref import stripe_update_ref
from compile.kernels.unifrac_stripes import StripeKernelConfig
from compile.model import ENGINES, example_args, lower_update, make_update_fn

CFG = StripeKernelConfig(n_samples=64, n_stripes=32, emb_batch=8, block_k=16)


def problem(cfg=CFG, seed=7):
    rng = np.random.default_rng(seed)
    half = rng.random((cfg.emb_batch, cfg.n_samples))
    emb = jnp.asarray(np.concatenate([half, half], axis=1), cfg.jdtype)
    lengths = jnp.asarray(rng.random(cfg.emb_batch), cfg.jdtype)
    num = jnp.zeros((cfg.n_stripes, cfg.n_samples), cfg.jdtype)
    return emb, lengths, num, jnp.zeros_like(num)


@pytest.mark.parametrize("engine", ENGINES)
def test_engines_agree(engine):
    emb, lengths, num, den = problem()
    got = make_update_fn(CFG, engine)(2, emb, lengths, num, den)
    ref = stripe_update_ref(emb, lengths, 2, num, den, metric=CFG.metric)
    np.testing.assert_allclose(got[0], ref[0], rtol=1e-10)
    np.testing.assert_allclose(got[1], ref[1], rtol=1e-10)


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        make_update_fn(CFG, "cuda")


@pytest.mark.parametrize("engine", ["jnp", "pallas_tiled"])
def test_lowered_hlo_text_parses(engine):
    """The HLO text must contain an entry computation with the artifact's
    parameter signature — the contract the rust loader relies on."""
    text = aot.to_hlo_text(lower_update(CFG, engine))
    assert "ENTRY" in text
    assert "f64[8,128]" in text  # emb [E, 2N]
    assert "f64[32,64]" in text  # accumulators [S, N]
    assert "s32[1]" in text  # start scalar


def test_example_args_match_config():
    args = example_args(CFG)
    assert args[1].shape == (CFG.emb_batch, 2 * CFG.n_samples)
    assert args[3].shape == (CFG.n_stripes, CFG.n_samples)
    assert args[0].dtype == jnp.int32


def test_artifact_plan_quick_and_full():
    quick = aot.artifact_plan(quick=True)
    full = aot.artifact_plan(quick=False)
    names = [n for n, _, _ in full]
    assert len(set(names)) == len(names), "artifact names must be unique"
    assert len(quick) == 4
    assert all(any(m in n for n, _, _ in full) for m in aot.METRICS)
    # the full plan retains the quick/test geometry artifacts
    assert {n for n, _, _ in quick} <= set(names)
    # fp32 and fp64 variants both present (paper §4)
    assert any("_f32_" in n for n in names) and any("_f64_" in n for n in names)
    # kernel-stage ablation artifacts present
    assert any("pallas_batched" in n for n in names)
    assert any("pallas_unbatched" in n for n in names)


def test_manifest_on_disk_if_built():
    """If `make artifacts` already ran, validate the manifest contents."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built yet")
    man = json.load(open(path))
    assert man["version"] == 1
    by_name = {e["name"]: e for e in man["artifacts"]}
    assert len(by_name) == len(man["artifacts"])
    for e in man["artifacts"]:
        f = os.path.join(os.path.dirname(path), e["file"])
        assert os.path.exists(f), f
        assert e["n_samples"] % e["block_k"] == 0
        assert e["vmem_bytes"] > 0
        assert e["engine"] in ENGINES
