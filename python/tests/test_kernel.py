"""Layer-1 correctness: Pallas stripe kernels vs the pure-jnp oracle.

This is the core correctness signal of the compile path: every kernel
stage, metric, dtype and a hypothesis-driven sweep of shapes must agree
with ``ref.stripe_update_ref`` to float tolerance.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import METRICS, metric_terms, stripe_update_ref
from compile.kernels.unifrac_stripes import (
    KERNEL_STAGES,
    StripeKernelConfig,
    make_stripe_kernel,
)

RNG = np.random.default_rng(0xDEAD)


def random_problem(cfg: StripeKernelConfig, rng=RNG, presence=None):
    """Build (emb, lengths, num, den) matching cfg; emb rows duplicated."""
    e, n, s = cfg.emb_batch, cfg.n_samples, cfg.n_stripes
    half = rng.random((e, n))
    if presence or (presence is None and cfg.metric == "unweighted"):
        half = (half < 0.3).astype(np.float64)
    emb = np.concatenate([half, half], axis=1)
    lengths = rng.random(e)
    num = rng.random((s, n))
    den = rng.random((s, n))
    dt = cfg.jdtype
    return (
        jnp.asarray(emb, dt),
        jnp.asarray(lengths, dt),
        jnp.asarray(num, dt),
        jnp.asarray(den, dt),
    )


def tol(cfg):
    return dict(rtol=1e-10, atol=1e-12) if cfg.dtype == "float64" else dict(rtol=2e-5, atol=1e-6)


def check(cfg: StripeKernelConfig, stage: str, start: int = 0):
    emb, lengths, num, den = random_problem(cfg)
    fn = make_stripe_kernel(cfg, stage)
    got_n, got_d = fn(start, emb, lengths, num, den)
    ref_n, ref_d = stripe_update_ref(
        emb, lengths, start, num, den, metric=cfg.metric, alpha=cfg.alpha
    )
    np.testing.assert_allclose(got_n, ref_n, **tol(cfg))
    np.testing.assert_allclose(got_d, ref_d, **tol(cfg))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("stage", KERNEL_STAGES)
def test_stage_metric_f64(metric, stage):
    cfg = StripeKernelConfig(
        n_samples=64, n_stripes=32, emb_batch=8, block_k=16, metric=metric, alpha=0.5
    )
    check(cfg, stage)


@pytest.mark.parametrize("metric", METRICS)
def test_tiled_f32(metric):
    cfg = StripeKernelConfig(
        n_samples=64,
        n_stripes=32,
        emb_batch=8,
        block_k=16,
        metric=metric,
        alpha=0.5,
        dtype="float32",
    )
    check(cfg, "pallas_tiled")


@pytest.mark.parametrize("start", [0, 1, 5, 31])
def test_stripe_block_offsets(start):
    """`start` shifts the v columns; stripes up to start+S-1 must stay in
    the duplicated row, mirroring how the rust coordinator blocks stripes."""
    cfg = StripeKernelConfig(n_samples=128, n_stripes=32, emb_batch=4, block_k=32)
    check(cfg, "pallas_tiled", start=start)


def test_zero_lengths_are_identity():
    cfg = StripeKernelConfig(n_samples=64, n_stripes=32, emb_batch=8, block_k=16)
    emb, _, num, den = random_problem(cfg)
    fn = make_stripe_kernel(cfg, "pallas_tiled")
    got_n, got_d = fn(0, emb, jnp.zeros((cfg.emb_batch,), cfg.jdtype), num, den)
    np.testing.assert_array_equal(got_n, num)
    np.testing.assert_array_equal(got_d, den)


def test_identical_samples_zero_numerator():
    """If every sample has the same profile, u == v and num is unchanged."""
    cfg = StripeKernelConfig(n_samples=64, n_stripes=32, emb_batch=8, block_k=16)
    row = np.tile(RNG.random((cfg.emb_batch, 1)), (1, 2 * cfg.n_samples))
    emb = jnp.asarray(row, cfg.jdtype)
    lengths = jnp.asarray(RNG.random(cfg.emb_batch), cfg.jdtype)
    num = jnp.zeros((cfg.n_stripes, cfg.n_samples), cfg.jdtype)
    den = jnp.zeros_like(num)
    got_n, got_d = make_stripe_kernel(cfg, "pallas_tiled")(0, emb, lengths, num, den)
    np.testing.assert_allclose(got_n, 0, atol=1e-14)
    assert float(jnp.max(got_d)) > 0


def test_generalized_alpha1_equals_weighted_normalized():
    base = dict(n_samples=64, n_stripes=32, emb_batch=8, block_k=16)
    cfg_g = StripeKernelConfig(**base, metric="generalized", alpha=1.0)
    cfg_w = StripeKernelConfig(**base, metric="weighted_normalized")
    emb, lengths, num, den = random_problem(cfg_w)
    g = make_stripe_kernel(cfg_g, "pallas_tiled")(0, emb, lengths, num, den)
    w = make_stripe_kernel(cfg_w, "pallas_tiled")(0, emb, lengths, num, den)
    np.testing.assert_allclose(g[0], w[0], rtol=1e-10)
    np.testing.assert_allclose(g[1], w[1], rtol=1e-10)


def test_unweighted_terms_are_xor_or():
    u = jnp.asarray([0.0, 0.0, 1.0, 1.0])
    v = jnp.asarray([0.0, 1.0, 0.0, 1.0])
    f_num, f_den = metric_terms("unweighted", u, v, 1.0)
    np.testing.assert_array_equal(f_num, [0, 1, 1, 0])  # XOR
    np.testing.assert_array_equal(f_den, [0, 1, 1, 1])  # OR


shape_strategy = st.tuples(
    st.sampled_from([16, 32, 64]),  # n_samples
    st.integers(1, 4),  # stripe blocks of 8
    st.sampled_from([1, 2, 5, 8]),  # emb batch
    st.sampled_from([8, 16]),  # block_k
    st.sampled_from(list(METRICS)),
    st.sampled_from(["float32", "float64"]),
    st.integers(0, 7),  # start
    st.integers(0, 2**31 - 1),  # seed
)


@settings(max_examples=40, deadline=None)
@given(shape_strategy)
def test_hypothesis_shapes_and_dtypes(params):
    """Property sweep: kernel == oracle across shapes/dtypes/starts."""
    n, sb, e, kb, metric, dtype, start, seed = params
    s = sb * 8
    if s + start > n or kb > n:
        return
    cfg = StripeKernelConfig(
        n_samples=n,
        n_stripes=s,
        emb_batch=e,
        block_k=kb,
        metric=metric,
        alpha=0.5,
        dtype=dtype,
    )
    rng = np.random.default_rng(seed)
    emb, lengths, num, den = random_problem(cfg, rng=rng)
    got_n, got_d = make_stripe_kernel(cfg, "pallas_tiled")(start, emb, lengths, num, den)
    ref_n, ref_d = stripe_update_ref(
        emb, lengths, start, num, den, metric=metric, alpha=0.5
    )
    np.testing.assert_allclose(got_n, ref_n, **tol(cfg))
    np.testing.assert_allclose(got_d, ref_d, **tol(cfg))


def test_config_validation():
    with pytest.raises(ValueError):
        StripeKernelConfig(n_samples=60, block_k=16)  # K_B must divide N
    with pytest.raises(ValueError):
        StripeKernelConfig(metric="nope")
    with pytest.raises(ValueError):
        StripeKernelConfig(n_samples=32, n_stripes=64)
    with pytest.raises(ValueError):
        make_stripe_kernel(StripeKernelConfig(), "pallas_mystery")


def test_vmem_estimate_monotone():
    small = StripeKernelConfig(n_samples=64, n_stripes=32, emb_batch=8, block_k=16)
    big = StripeKernelConfig(n_samples=256, n_stripes=128, emb_batch=32, block_k=64)
    assert small.vmem_bytes() < big.vmem_bytes()
    # production tile must fit a 16 MiB VMEM with double-buffer headroom
    assert big.vmem_bytes() * 2 < 16 * 2**20
