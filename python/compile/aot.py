"""AOT lowering driver: jax -> HLO text artifacts + manifest.json.

Run once at build time (``make artifacts``). Each artifact is one
(engine, metric, dtype, tile-config) combination of the Layer-2 stripe
update, written as **HLO text** — NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

The manifest records, per artifact, everything the rust runtime needs to
pick and drive it: shapes, dtype, metric/alpha, engine, tiling and the
estimated VMEM working set of one kernel program (DESIGN.md §Perf).

Usage: ``python -m compile.aot --out ../artifacts [--quick] [--force]``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)  # fp64 artifacts (paper §4)

from jax._src.lib import xla_client as xc  # noqa: E402

from .kernels.unifrac_stripes import StripeKernelConfig  # noqa: E402
from .model import lower_update  # noqa: E402

#: Production chunk geometry: sample-chunk width N, stripe-block S, Figure-2
#: embedding batch E, Figure-3 step_size K_B. Rust pads/partitions every
#: problem onto these tiles (coordinator::partition).
PROD = dict(n_samples=256, n_stripes=128, emb_batch=32, block_k=64)
#: Small geometry for fast integration tests.
TEST = dict(n_samples=64, n_stripes=32, emb_batch=8, block_k=16)
#: Wide chunk for larger PJRT runs (jnp engine; the [E,S,N] gather stays
#: fused, so E is kept small to bound the working set).
LARGE = dict(n_samples=1024, n_stripes=512, emb_batch=16, block_k=128)

METRICS = ("unweighted", "weighted_normalized", "weighted_unnormalized", "generalized")
DTYPES = ("float32", "float64")  # the paper's §4 fp32-vs-fp64 axis


def artifact_plan(quick: bool):
    """Yield (name, StripeKernelConfig, engine) for every artifact to build."""
    plan = []

    def add(engine, geom, **kw):
        cfg = StripeKernelConfig(**geom, **kw)
        short = {"float32": "f32", "float64": "f64"}[cfg.dtype]
        name = (
            f"stripes_{cfg.metric}_{engine}_{short}"
            f"_n{cfg.n_samples}_s{cfg.n_stripes}_e{cfg.emb_batch}_k{cfg.block_k}"
        )
        plan.append((name, cfg, engine))

    # Test geometry: both run-time engines, two representative metrics.
    for engine in ("jnp", "pallas_tiled"):
        for metric in ("unweighted", "weighted_normalized"):
            add(engine, TEST, metric=metric, dtype="float64")
    if quick:
        return plan

    # Production geometry: full metric x dtype grid for both engines.
    for engine in ("jnp", "pallas_tiled"):
        for metric in METRICS:
            for dtype in DTYPES:
                alpha = 0.5 if metric == "generalized" else 1.0
                add(engine, PROD, metric=metric, dtype=dtype, alpha=alpha)
    # Kernel-stage ablation artifacts (Figures 1->3 story at L1).
    for engine in ("pallas_batched", "pallas_unbatched"):
        add(engine, PROD, metric="weighted_normalized", dtype="float64")
    # Large chunk geometry (jnp engine only: the XLA-fused formulation
    # scales to wider chunks without interpret-mode kernel overhead).
    for dtype in DTYPES:
        for metric in ("unweighted", "weighted_normalized"):
            add("jnp", LARGE, metric=metric, dtype=dtype)
    return plan


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, quick: bool = False, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    entries = []
    plan = artifact_plan(quick)
    for i, (name, cfg, engine) in enumerate(plan):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        if force or not os.path.exists(path):
            text = to_hlo_text(lower_update(cfg, engine))
            with open(path, "w") as f:
                f.write(text)
            status = "built"
        else:
            status = "cached"
        digest = hashlib.sha256(open(path, "rb").read()).hexdigest()[:16]
        entries.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "engine": engine,
                "metric": cfg.metric,
                "alpha": cfg.alpha,
                "dtype": cfg.dtype,
                "n_samples": cfg.n_samples,
                "n_stripes": cfg.n_stripes,
                "emb_batch": cfg.emb_batch,
                "block_k": cfg.block_k,
                "vmem_bytes": cfg.vmem_bytes(),
                "sha256_16": digest,
            }
        )
        print(f"[{i + 1}/{len(plan)}] {status} {name}", flush=True)
    manifest = {"version": 1, "artifacts": entries}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path} ({len(entries)} artifacts)")
    return manifest


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--quick", action="store_true", help="test geometry only")
    p.add_argument("--force", action="store_true", help="rebuild even if cached")
    a = p.parse_args(argv)
    build(a.out, quick=a.quick, force=a.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
