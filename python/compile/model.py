"""Layer-2 JAX compute graph: the stripe-batch update step.

The Layer-3 rust coordinator drives Striped UniFrac as a sequence of
*stripe-batch updates*: for each batch of E node embeddings it invokes one
compiled update over a (stripe-block x sample-chunk) accumulator pair.
This module builds the jax function for one such update — either routed
through the Layer-1 Pallas kernel (``pallas_*`` engines) or through the
fully-vectorized jnp formulation (``jnp`` engine, which XLA fuses into a
single gather + FMA pipeline) — so both lower into the same AOT artifact
shape and are interchangeable at run time.

Signature of every engine (shapes static per artifact):

    (start i32[1], emb dt[E, 2N], lengths dt[E], num dt[S, N], den dt[S, N])
        -> (num' dt[S, N], den' dt[S, N])

Python is build-time only: ``aot.py`` lowers these functions to HLO text
once; rust loads and executes the artifacts via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.ref import stripe_update_ref
from .kernels.unifrac_stripes import (
    KERNEL_STAGES,
    StripeKernelConfig,
    make_stripe_kernel,
)

#: All run-time engines an artifact can embody.
ENGINES = ("jnp",) + KERNEL_STAGES


def make_update_fn(cfg: StripeKernelConfig, engine: str = "pallas_tiled"):
    """Return the stripe-batch update callable for ``cfg`` and ``engine``."""
    if engine == "jnp":
        dt = cfg.jdtype

        def fn(start, emb, lengths, num, den):
            start = jnp.asarray(start, jnp.int32).reshape((1,))[0]
            return stripe_update_ref(
                emb.astype(dt),
                lengths.astype(dt),
                start,
                num,
                den,
                metric=cfg.metric,
                alpha=cfg.alpha,
            )

        return fn
    if engine in KERNEL_STAGES:
        return make_stripe_kernel(cfg, engine)
    raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")


def example_args(cfg: StripeKernelConfig):
    """Abstract arguments for AOT lowering of one artifact."""
    dt = cfg.jdtype
    return (
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((cfg.emb_batch, 2 * cfg.n_samples), dt),
        jax.ShapeDtypeStruct((cfg.emb_batch,), dt),
        jax.ShapeDtypeStruct((cfg.n_stripes, cfg.n_samples), dt),
        jax.ShapeDtypeStruct((cfg.n_stripes, cfg.n_samples), dt),
    )


def lower_update(cfg: StripeKernelConfig, engine: str):
    """jit + lower one artifact; returns the jax Lowered object."""
    fn = make_update_fn(cfg, engine)
    return jax.jit(fn).lower(*example_args(cfg))
