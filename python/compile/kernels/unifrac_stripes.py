"""Layer-1 Pallas kernels for the Striped UniFrac stripe update.

The kernel is the Pallas re-expression of the paper's final OpenACC loop
nest (Figure 3):

    #pragma acc parallel loop collapse(3) present(emb, dm_stripes_buf, length)
    for (sk = 0; sk < sample_steps; sk++)        -> grid axis 1 (sample block)
      for (stripe = start; stripe < stop; ++)    -> grid axis 0 (stripe)
        for (ik = 0; ik < step_size; ik++)       -> vector lanes (block width)
          my_stripe = dm_stripe[k]               -> register accumulation
          #pragma acc loop seq
          for (e = 0; e < filled_embs; e++)      -> in-kernel fori_loop
            my_stripe += f(emb[e,k], emb[e,k+stripe+1]) * length[e]
          dm_stripe[k] = my_stripe               -> ONE write per column

Hardware adaptation (see DESIGN.md §2): OpenACC gangs become the Pallas
grid, `step_size` becomes the BlockSpec block width K_B, the paper's
"batch many input buffers per kernel invocation" (Figure 2) is the E axis
of `emb` consumed by an in-kernel sequential loop that accumulates in
registers/VMEM and writes the output block exactly once, and the paper's
"remove the manual 4-way unroll" insight (§3) corresponds to letting the
block width be the vector axis instead of hand-unrolling k.

Three kernel *stages* are provided so the paper's optimization story is
reproducible at the kernel level (bench: ablation_stages):

  - ``pallas_batched``  : Figure 2 — grid over stripes only; each program
                          walks the whole sample axis (no K-tiling).
  - ``pallas_tiled``    : Figure 3 — grid (stripe, sample-block); the
                          production kernel.
  - ``pallas_unbatched``: pre-Figure-2 — one embedding per grid step along
                          a third grid axis; accumulators are re-read and
                          re-written per embedding (the "repeated updating
                          of the main memory buffer" the paper calls out).

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so correctness is validated through the interpreter
and device performance is modeled analytically (rust ``devicemodel``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import METRICS, metric_terms


@dataclass(frozen=True)
class StripeKernelConfig:
    """Static shape/tiling configuration for one AOT artifact.

    Attributes mirror the paper's parameters: ``n_samples`` is the chunk
    width N (padded), ``n_stripes`` the stripe-block height S,
    ``emb_batch`` the Figure-2 batch size E (filled_embs), ``block_k`` the
    Figure-3 ``step_size`` K_B, ``metric``/``alpha`` the UniFrac variant
    and ``dtype`` the compute precision (paper §4).
    """

    n_samples: int = 256
    n_stripes: int = 128
    emb_batch: int = 32
    block_k: int = 64
    metric: str = "weighted_normalized"
    alpha: float = 1.0
    dtype: str = "float64"

    def __post_init__(self):
        if self.metric not in METRICS:
            raise ValueError(f"unknown metric {self.metric!r}")
        if self.n_samples % self.block_k != 0:
            raise ValueError(
                f"block_k {self.block_k} must divide n_samples {self.n_samples}"
            )
        if self.n_samples < 2 or self.n_stripes < 1 or self.emb_batch < 1:
            raise ValueError("degenerate kernel config")
        if self.n_stripes > self.n_samples:
            # stripe index must stay < n_samples so that the shifted column
            # k + stripe + 1 stays inside the duplicated 2N row.
            raise ValueError("n_stripes may not exceed n_samples")

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def vmem_bytes(self) -> int:
        """Estimated VMEM working set of one ``pallas_tiled`` program:
        full emb block + lengths + in/out accumulator tiles."""
        item = self.jdtype.itemsize
        emb = self.emb_batch * 2 * self.n_samples * item
        acc = 4 * self.block_k * item  # num/den in + out tiles
        return emb + self.emb_batch * item + acc


def _accumulate(cfg: StripeKernelConfig, emb_ref, len_ref, stripe, k0, width):
    """Shared inner loop: fold the E embeddings into (num, den) vectors of
    ``width`` lanes for stripe ``stripe`` and sample offset ``k0``.

    Accumulation happens in registers (carry of the fori_loop); the caller
    performs the single write to the output block — the Figure-2 insight.
    """
    dt = cfg.jdtype
    zero = jnp.zeros((width,), dt)

    def body(e, carry):
        acc_n, acc_d = carry
        u = emb_ref[e, pl.dslice(k0, width)]
        v = emb_ref[e, pl.dslice(k0 + stripe + 1, width)]
        ln = len_ref[e]
        f_num, f_den = metric_terms(cfg.metric, u, v, cfg.alpha)
        return acc_n + ln * f_num, acc_d + ln * f_den

    return jax.lax.fori_loop(0, cfg.emb_batch, body, (zero, zero))


def _tiled_kernel(cfg, start_ref, emb_ref, len_ref, num_in, den_in, num_out, den_out):
    """Figure-3 kernel: program = (stripe, sample-block)."""
    s = pl.program_id(0)
    kb = pl.program_id(1)
    k0 = kb * cfg.block_k
    stripe = start_ref[0] + s
    acc_n, acc_d = _accumulate(cfg, emb_ref, len_ref, stripe, k0, cfg.block_k)
    num_out[0, :] = num_in[0, :] + acc_n
    den_out[0, :] = den_in[0, :] + acc_d


def _batched_kernel(cfg, start_ref, emb_ref, len_ref, num_in, den_in, num_out, den_out):
    """Figure-2 kernel: program = stripe; whole sample row per program."""
    s = pl.program_id(0)
    stripe = start_ref[0] + s
    acc_n, acc_d = _accumulate(cfg, emb_ref, len_ref, stripe, 0, cfg.n_samples)
    num_out[0, :] = num_in[0, :] + acc_n
    den_out[0, :] = den_in[0, :] + acc_d


def _unbatched_kernel(cfg, start_ref, emb_ref, len_ref, num_in, den_in, num_out, den_out):
    """Pre-Figure-2 kernel: one embedding per program along grid axis 2.

    The accumulator block is read and written once PER EMBEDDING — the
    exact "repeated updating of the main memory buffer" traffic pattern
    the paper identifies as the bottleneck of the initial port.
    """
    s = pl.program_id(0)
    kb = pl.program_id(1)
    e = pl.program_id(2)
    k0 = kb * cfg.block_k
    stripe = start_ref[0] + s
    u = emb_ref[e, pl.dslice(k0, cfg.block_k)]
    v = emb_ref[e, pl.dslice(k0 + stripe + 1, cfg.block_k)]
    ln = len_ref[e]
    f_num, f_den = metric_terms(cfg.metric, u, v, cfg.alpha)

    # On the first embedding the output block still holds garbage (pallas
    # does not pre-copy the aliased input), so seed it from the input.
    @pl.when(e == 0)
    def _seed():
        num_out[0, :] = num_in[0, :]
        den_out[0, :] = den_in[0, :]

    num_out[0, :] += ln * f_num
    den_out[0, :] += ln * f_den


#: kernel-stage name -> (body fn, needs revisiting grid) registry
KERNEL_STAGES = ("pallas_tiled", "pallas_batched", "pallas_unbatched")


def make_stripe_kernel(cfg: StripeKernelConfig, stage: str = "pallas_tiled"):
    """Build the jax-callable stripe update for one static config.

    Returns ``fn(start_i32[1], emb[E,2N], lengths[E], num[S,N], den[S,N])
    -> (num', den')``.
    """
    dt = cfg.jdtype
    n, s_cnt, e_cnt = cfg.n_samples, cfg.n_stripes, cfg.emb_batch
    kb_cnt = n // cfg.block_k

    whole = lambda *shape: pl.BlockSpec(shape, lambda *_: tuple(0 for _ in shape))

    if stage == "pallas_tiled":
        grid = (s_cnt, kb_cnt)
        acc_spec = pl.BlockSpec((1, cfg.block_k), lambda s, kb: (s, kb))
        body = _tiled_kernel
    elif stage == "pallas_batched":
        grid = (s_cnt,)
        acc_spec = pl.BlockSpec((1, n), lambda s: (s, 0))
        body = _batched_kernel
    elif stage == "pallas_unbatched":
        grid = (s_cnt, kb_cnt, e_cnt)
        acc_spec = pl.BlockSpec((1, cfg.block_k), lambda s, kb, e: (s, kb))
        body = _unbatched_kernel
    else:
        raise ValueError(f"unknown kernel stage {stage!r}")

    in_specs = [
        whole(1),          # start (scalar, kept as [1] for CPU interpret)
        whole(e_cnt, 2 * n),  # emb
        whole(e_cnt),      # lengths
        acc_spec,          # num in
        acc_spec,          # den in
    ]
    out_specs = [acc_spec, acc_spec]

    kernel = pl.pallas_call(
        functools.partial(body, cfg),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((s_cnt, n), dt),
            jax.ShapeDtypeStruct((s_cnt, n), dt),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )

    def fn(start, emb, lengths, num, den):
        start = jnp.asarray(start, jnp.int32).reshape((1,))
        return tuple(kernel(start, emb.astype(dt), lengths.astype(dt), num, den))

    return fn
