"""Pure-jnp oracle for the Striped UniFrac stripe-update step.

This is the CORE correctness signal for Layer 1: the Pallas kernels in
``unifrac_stripes.py`` must agree with these functions to float tolerance
for every metric / dtype / shape combination (see ``python/tests``).

The stripe-update step is the hot loop of the paper (Figures 1-3):
given a batch of node "embeddings" (per-sample mass under a tree node)
and the node branch lengths, accumulate into the stripe numerator and
denominator buffers

    num[s, k] += length[e] * f_num(u, v)
    den[s, k] += length[e] * f_den(u, v)

with ``u = emb[e, k]`` and ``v = emb[e, k + s + start + 1]`` where the
embedding row is circular with period ``n_samples`` (the caller passes the
row duplicated to length ``2 * n_samples``, exactly like the original
Striped UniFrac C++ implementation).

Metric definitions (u, v are per-sample masses; presence/absence is
encoded as 0.0 / 1.0 for the unweighted metric):

  unweighted            f_num = |u - v|            f_den = max(u, v)
                        (for 0/1 inputs these are XOR and OR)
  weighted_normalized   f_num = |u - v|            f_den = u + v
  weighted_unnormalized f_num = |u - v|            f_den = 0  (unused)
  generalized(alpha)    f_num = (u+v)^(a-1)|u-v|   f_den = (u+v)^a
                        (both 0 where u + v == 0)

``generalized`` with alpha=1 reduces to weighted_normalized.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Metric names, in the canonical order used across the repo (rust mirrors
#: this ordering in ``unifrac::Metric``).
METRICS = (
    "unweighted",
    "weighted_normalized",
    "weighted_unnormalized",
    "generalized",
)


def metric_terms(metric: str, u, v, alpha: float):
    """Return ``(f_num(u, v), f_den(u, v))`` for one metric.

    Shared by the oracle and by the Pallas kernels so the math is written
    exactly once.
    """
    d = jnp.abs(u - v)
    if metric == "unweighted":
        return d, jnp.maximum(u, v)
    if metric == "weighted_normalized":
        return d, u + v
    if metric == "weighted_unnormalized":
        return d, jnp.zeros_like(d)
    if metric == "generalized":
        s = u + v
        # (u+v)^(alpha-1) diverges at s == 0; the metric defines both
        # terms as 0 there (no mass under the branch in either sample).
        safe = jnp.where(s > 0, s, 1)
        num = jnp.where(s > 0, safe ** (alpha - 1) * d, 0)
        den = jnp.where(s > 0, safe**alpha, 0)
        return num.astype(d.dtype), den.astype(d.dtype)
    raise ValueError(f"unknown metric {metric!r}")


def stripe_update_ref(emb, lengths, start, num, den, *, metric="weighted_normalized", alpha=1.0):
    """Oracle stripe update.

    Shapes: ``emb [E, 2N]`` (row circularly duplicated), ``lengths [E]``,
    ``start`` scalar int32 (global index of the first stripe in this
    block), ``num``/``den`` ``[S, N]``. Returns the updated ``(num, den)``.
    """
    e_cnt, two_n = emb.shape
    s_cnt, n = num.shape
    if two_n != 2 * n:
        raise ValueError(f"emb row length {two_n} != 2 * n_samples {2 * n}")
    start = jnp.asarray(start, jnp.int32).reshape(())
    k = jnp.arange(n)
    s = jnp.arange(s_cnt)
    # v-column index for (stripe, sample): k + stripe + 1, stripes offset
    # globally by `start` (the coordinator splits stripes into blocks).
    idx = k[None, :] + (s[:, None] + start + 1)  # [S, N], values in [1, 2N)
    u = emb[:, :n][:, None, :]  # [E, 1, N]
    v = emb[:, idx]  # [E, S, N]
    f_num, f_den = metric_terms(metric, u, v, alpha)
    w = lengths[:, None, None]
    return (
        num + jnp.sum(w * f_num, axis=0, dtype=num.dtype),
        den + jnp.sum(w * f_den, axis=0, dtype=den.dtype),
    )


def distance_from_stripes(num, den, metric="weighted_normalized"):
    """Finalize stripes into distances: ``num/den`` for normalized metrics,
    ``num`` for weighted_unnormalized; 0 where the denominator is 0."""
    if metric == "weighted_unnormalized":
        return num
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1), 0)
