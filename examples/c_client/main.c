/* c_client — end-to-end consumer of the UniFrac C shared library.
 *
 * Computes a distance matrix via ssu_one_off, recomputes it as three
 * stripe partials (round-tripping one through save/load), merges them,
 * verifies the merge is exactly equal to the one-shot run, and writes
 * the matrix as TSV (byte-identical to the Rust CLI's --output).
 *
 * Build (from the repo root, after `cargo build --release` in rust/):
 *   cc -O2 -Wall -Werror examples/c_client/main.c \
 *      -Iinclude -Lrust/target/release -lunifrac -lm -o c_client
 * Run:
 *   LD_LIBRARY_PATH=rust/target/release \
 *     ./c_client table.tsv tree.nwk weighted_normalized out.tsv
 */

#include <stdio.h>
#include <stdlib.h>
#include <string.h>

#include "unifrac.h"

#define N_PARTIALS 3

static int die(const char *what, int rc) {
  fprintf(stderr, "c_client: %s failed: %s (code %d: %s)\n", what,
          ssu_last_error(), rc, ssu_error_name(rc));
  return 1;
}

int main(int argc, char **argv) {
  if (argc != 5) {
    fprintf(stderr,
            "usage: %s TABLE.tsv TREE.nwk METRIC OUT.tsv\n"
            "  METRIC: unweighted | weighted_normalized | "
            "weighted_unnormalized | generalized\n",
            argv[0]);
    return 2;
  }
  const char *table = argv[1];
  const char *tree = argv[2];
  const char *metric = argv[3];
  const char *out_tsv = argv[4];

  printf("c_client: %s\n", ssu_version());

  /* ---- one_off: the full matrix in one call ---- */
  SsuMatrix *full = NULL;
  int rc = ssu_one_off(table, tree, metric, 1.0, /*fp32=*/0, /*threads=*/1,
                       &full);
  if (rc != SSU_OK) return die("ssu_one_off", rc);
  unsigned n = ssu_matrix_n_samples(full);
  printf("c_client: one_off ok — %u samples, d(%s,%s) = %.6f\n", n,
         ssu_matrix_sample_id(full, 0), ssu_matrix_sample_id(full, 1),
         ssu_matrix_get(full, 0, 1));

  /* ---- partial: the same job as N independent stripe splits ---- */
  SsuPartial *parts[N_PARTIALS] = {0};
  for (unsigned i = 0; i < N_PARTIALS; i++) {
    rc = ssu_partial(table, tree, metric, 1.0, 0, 1, i, N_PARTIALS,
                     &parts[i]);
    if (rc != SSU_OK) return die("ssu_partial", rc);
    printf("c_client: partial %u/%u covers stripes %u..+%u\n", i, N_PARTIALS,
           ssu_partial_stripe_start(parts[i]),
           ssu_partial_stripe_count(parts[i]));
  }

  /* persist one partial and reload it — the cross-machine path */
  const char *part_path = "c_client_partial.bin";
  rc = ssu_partial_save(parts[1], part_path);
  if (rc != SSU_OK) return die("ssu_partial_save", rc);
  ssu_partial_free(parts[1]);
  parts[1] = NULL;
  rc = ssu_partial_load(part_path, &parts[1]);
  if (rc != SSU_OK) return die("ssu_partial_load", rc);
  remove(part_path);

  /* ---- merge and verify: exactly equal to one_off ---- */
  SsuMatrix *merged = NULL;
  rc = ssu_merge_partials((const SsuPartial *const *)parts, N_PARTIALS,
                          &merged);
  if (rc != SSU_OK) return die("ssu_merge_partials", rc);
  double max_diff = 0.0;
  for (unsigned i = 0; i < n; i++) {
    for (unsigned j = 0; j < n; j++) {
      double d = ssu_matrix_get(full, i, j) - ssu_matrix_get(merged, i, j);
      if (d < 0) d = -d;
      if (d > max_diff) max_diff = d;
    }
  }
  printf("c_client: merge vs one_off max |diff| = %g\n", max_diff);
  if (max_diff != 0.0) {
    fprintf(stderr, "c_client: FAIL — merged partials differ from one_off\n");
    return 1;
  }

  /* a merge with a hole must be rejected with the merge status code */
  SsuMatrix *bad = NULL;
  rc = ssu_merge_partials((const SsuPartial *const *)parts, N_PARTIALS - 1,
                          &bad);
  if (rc != SSU_ERR_MERGE) {
    fprintf(stderr, "c_client: FAIL — gap merge returned %d, want %d\n", rc,
            SSU_ERR_MERGE);
    return 1;
  }
  printf("c_client: gap rejected as expected (%s: %s)\n", ssu_error_name(rc),
         ssu_last_error());

  /* ---- write the TSV for the CI diff against the Rust CLI ---- */
  rc = ssu_matrix_write_tsv(merged, out_tsv);
  if (rc != SSU_OK) return die("ssu_matrix_write_tsv", rc);
  printf("c_client: wrote %s\n", out_tsv);

  for (unsigned i = 0; i < N_PARTIALS; i++) ssu_partial_free(parts[i]);
  ssu_matrix_free(full);
  ssu_matrix_free(merged);
  printf("c_client: OK\n");
  return 0;
}
