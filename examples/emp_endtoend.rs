//! End-to-end driver: the full three-layer stack on an EMP-shaped
//! workload (DESIGN.md: the mandated e2e validation run), driven
//! entirely through the `UniFracJob` facade.
//!
//! Pipeline exercised, in order:
//!   1. synthetic EMP-like dataset (substitute for the EMP release);
//!   2. Layer-3 embedding producer (postorder DP over the phylogeny);
//!   3. the AOT Pallas stripe kernel (Layer 1) inside the jax stripe
//!      graph (Layer 2), loaded from `artifacts/` and executed via PJRT
//!      with device-resident accumulators;
//!   4. stripe assembly -> condensed matrix;
//!   5. cross-validation against the independent CPU engine and the
//!      naive oracle;
//!   6. downstream analysis (PCoA + PERMANOVA), the end product a
//!      microbiome study actually consumes.
//!
//! Results of this run are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example emp_endtoend
//! ```

use unifrac::stats::{mantel, pcoa, permanova};
use unifrac::synth::SynthSpec;
use unifrac::unifrac::compute_unifrac_naive;
use unifrac::{Backend, Metric, UniFracJob};

fn main() -> unifrac::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("UNIFRAC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }

    // EMP-shaped workload at the PJRT production chunk width (N=256).
    let n = 250; // deliberately not a power of two: exercises padding
    let (tree, table) = SynthSpec::emp_like(n, 2026).generate();
    println!(
        "== workload: {} samples x {} features (density {:.4}), {} tree nodes",
        table.n_samples(),
        table.n_features(),
        table.density(),
        tree.n_nodes()
    );

    let metric = Metric::WeightedNormalized;

    // --- full stack through PJRT (pallas kernel artifact, resident) ---
    let t0 = std::time::Instant::now();
    let out = UniFracJob::new(&tree, &table)
        .metric(metric)
        .backend(Backend::Pjrt { artifact: "pallas_tiled".into(), resident: true })
        .artifacts_dir(artifacts.clone())
        .run_output()?;
    let pjrt_secs = t0.elapsed().as_secs_f64();
    println!(
        "== PJRT/pallas run: {:.2}s wall, artifact {}, {} embeddings in {} batches, {:.3e} updates/s",
        pjrt_secs,
        out.metrics.artifact.as_deref().unwrap_or("?"),
        out.metrics.embeddings,
        out.metrics.batches,
        out.metrics.updates_per_second()
    );

    // --- the jnp-engine artifact (same L2 graph, no pallas) ---
    let t1 = std::time::Instant::now();
    let out_jnp = UniFracJob::new(&tree, &table)
        .metric(metric)
        .backend(Backend::Pjrt { artifact: "jnp".into(), resident: true })
        .artifacts_dir(artifacts)
        .run_output()?;
    println!(
        "== PJRT/jnp run:    {:.2}s wall (same HLO interface, XLA-fused formulation)",
        t1.elapsed().as_secs_f64()
    );

    // --- independent CPU engine + naive oracle cross-checks ---
    let cpu = UniFracJob::new(&tree, &table).metric(metric).threads(0).run()?;
    let naive = compute_unifrac_naive(&tree, &table, metric)?;
    let d_pjrt_cpu = out.dm.max_abs_diff(&cpu);
    let d_pjrt_jnp = out.dm.max_abs_diff(&out_jnp.dm);
    let d_cpu_naive = cpu.max_abs_diff(&naive);
    println!("== correctness:");
    println!("   |pallas-PJRT - CPU tiled|   = {d_pjrt_cpu:.3e}");
    println!("   |pallas-PJRT - jnp-PJRT|    = {d_pjrt_jnp:.3e}");
    println!("   |CPU tiled   - naive oracle| = {d_cpu_naive:.3e}");
    assert!(d_pjrt_cpu < 1e-9 && d_pjrt_jnp < 1e-9 && d_cpu_naive < 1e-9);

    // --- downstream: ordination + a grouping test, like an EMP analysis ---
    let ord = pcoa(&out.dm, 3, 1);
    println!(
        "== PCoA: leading 3 axes explain {:.1}% / {:.1}% / {:.1}%",
        ord.proportion_explained.first().copied().unwrap_or(0.0) * 100.0,
        ord.proportion_explained.get(1).copied().unwrap_or(0.0) * 100.0,
        ord.proportion_explained.get(2).copied().unwrap_or(0.0) * 100.0,
    );
    // split samples along PCoA axis 1 into two "environments" and verify
    // PERMANOVA finds the (by construction) real structure
    let axis = &ord.coordinates[0];
    let median = {
        let mut v = axis.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let groups: Vec<usize> = axis.iter().map(|&x| usize::from(x > median)).collect();
    let perm = permanova(&out.dm, &groups, 199, 3);
    println!(
        "== PERMANOVA on PCoA-axis-1 split: pseudo-F = {:.2}, p = {:.3}",
        perm.pseudo_f, perm.p_value
    );

    // --- sanity: PJRT and CPU matrices are statistically identical ---
    let mr = mantel(&out.dm, &cpu, 99, 5);
    println!("== Mantel(PJRT, CPU) R^2 = {:.6}", mr.r2);
    assert!(mr.r2 > 0.999999);

    println!("== end-to-end OK");
    Ok(())
}
