//! Paper §4 reproduction: fp32 vs fp64 UniFrac are statistically
//! identical (the paper reports Mantel R² = 0.99999, p < 0.001 on EMP),
//! driven through the `UniFracJob` facade's precision axis.
//!
//! The synthetic workload uses a large log-normal sigma so per-cell
//! counts span ~6 orders of magnitude — the "high dynamic range" case
//! the paper flags as the only risk for fp32.
//!
//! ```bash
//! cargo run --release --example fp32_validation [n_samples]
//! ```

use unifrac::stats::{mantel, pcoa};
use unifrac::synth::SynthSpec;
use unifrac::util::pearson;
use unifrac::{FpWidth, Metric, UniFracJob};

fn main() -> unifrac::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(192);
    let spec = SynthSpec {
        n_samples: n,
        n_features: (n * 8).max(512),
        density: 0.01,
        lognormal_sigma: 3.5, // stress the dynamic range (paper §4 caveat)
        zipf_exponent: 1.2,
        seed: 7,
    };
    let (tree, table) = spec.generate();
    println!(
        "workload: {} samples, {} features, lognormal sigma {} (high dynamic range)",
        table.n_samples(),
        table.n_features(),
        spec.lognormal_sigma
    );

    for metric in [Metric::Unweighted, Metric::WeightedNormalized, Metric::Generalized(0.5)] {
        // same job, both precisions — FpWidth is a first-class knob on
        // the facade, so no generic plumbing leaks into user code
        let job = UniFracJob::new(&tree, &table).metric(metric).threads(0);
        let d64 = job.run()?;
        let d32 = UniFracJob::new(&tree, &table)
            .metric(metric)
            .threads(0)
            .precision(FpWidth::F32)
            .run()?;

        let res = mantel(&d64, &d32, 999, 11);
        let max_diff = d64.max_abs_diff(&d32);

        // downstream robustness: the paper argues fp32 suffices
        // "especially ... after dimensionality reduction"
        let p64 = pcoa(&d64, 1, 1);
        let p32 = pcoa(&d32, 1, 1);
        let axis_r = if p64.coordinates.is_empty() || p32.coordinates.is_empty() {
            f64::NAN
        } else {
            pearson(&p64.coordinates[0], &p32.coordinates[0]).abs()
        };

        println!("\n{metric}:");
        println!("  Mantel R^2      = {:.7}   (paper: 0.99999)", res.r2);
        println!("  p-value         = {:.4}      (paper: < 0.001)", res.p_value);
        println!("  max |d64 - d32| = {max_diff:.3e}");
        println!("  PCoA axis-1 |r| = {axis_r:.7}");
        assert!(res.r2 > 0.9999, "fp32 equivalence failed for {metric}");
        assert!(res.p_value < 0.01);
    }
    println!("\nfp32 validation OK — fp32 is adequate for discovery work (paper §4)");
    Ok(())
}
