//! Table-2 style distributed run through the `UniFracJob` facade:
//! partition the stripe set over many simulated chips, time each in
//! isolation, demonstrate the partial/merge lifecycle that splits the
//! same job across *processes or machines*, and compare against the
//! device models.
//!
//! ```bash
//! cargo run --release --example distributed_chips [n_samples] [chips]
//! ```

use unifrac::devicemodel::{predict_seconds, stage_workload, Dtype, V100, XEON_E5_2680V4};
use unifrac::matrix::total_stripes;
use unifrac::synth::SynthSpec;
use unifrac::unifrac::EngineKind;
use unifrac::{merge_partials, Metric, UniFracJob};

fn main() -> unifrac::Result<()> {
    let mut args = std::env::args().skip(1);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(512);
    let chips: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(8);

    let (tree, table) = SynthSpec::emp_like(n, 99).generate();
    println!(
        "workload: {} samples, {} tree nodes, {} chips",
        table.n_samples(),
        tree.n_nodes(),
        chips
    );

    // sequential mode = isolated per-chip timing (the paper's Table 2 rows)
    let seq = UniFracJob::new(&tree, &table)
        .metric(Metric::WeightedNormalized)
        .chips(chips)
        .parallel(false)
        .run_output()?;
    println!("\nsequential (isolated chips):");
    let per: &[f64] = &seq.metrics.per_chip_seconds;
    let mean = per.iter().sum::<f64>() / per.len() as f64;
    let max = seq.metrics.max_chip_seconds();
    println!("  per-chip mean {:.3}s  max {:.3}s", mean, max);
    println!(
        "  aggregated    {:.3}s (the paper's chip-hours analogue)",
        seq.metrics.aggregate_chip_seconds()
    );
    println!("  load imbalance (max/mean) = {:.3}", max / mean);

    // parallel mode: actual wall-clock speedup on this host
    let par = UniFracJob::new(&tree, &table)
        .metric(Metric::WeightedNormalized)
        .chips(chips)
        .parallel(true)
        .run_output()?;
    println!("\nparallel (threaded chips):");
    println!(
        "  wall {:.3}s  vs sequential aggregate {:.3}s  => speedup {:.2}x",
        par.metrics.seconds_total,
        seq.metrics.aggregate_chip_seconds(),
        seq.metrics.aggregate_chip_seconds() / par.metrics.seconds_total
    );
    assert!(par.dm.max_abs_diff(&seq.dm) < 1e-12, "parallel/sequential mismatch");

    // the cross-machine version of the same split: each "chip" computes
    // a stripe partial (serializable — ship it anywhere), the leader
    // merges; bit-identical to the in-process run
    let part_job = UniFracJob::new(&tree, &table).metric(Metric::WeightedNormalized);
    let parts = (0..chips)
        .map(|i| part_job.run_partial_index(i, chips))
        .collect::<unifrac::Result<Vec<_>>>()?;
    let merged = merge_partials(&parts)?;
    let reference = part_job.run()?;
    println!("\npartial/merge over {} ranges:", parts.len());
    println!(
        "  merged vs one-shot max |diff| = {:e} (exact by construction)",
        merged.max_abs_diff(&reference)
    );
    assert_eq!(merged.max_abs_diff(&reference), 0.0);

    // device-model view of the same partitioning at paper scale
    println!("\ndevice-model projection (113,721 samples, per the paper's Table 2):");
    let (big_n, big_t) =
        (unifrac::devicemodel::BIG_N_SAMPLES, unifrac::devicemodel::BIG_TREE_NODES);
    let w = stage_workload(EngineKind::Tiled, big_n, total_stripes(big_n), big_t, 64, Dtype::F64);
    let cpu_h = predict_seconds(&XEON_E5_2680V4, &w, Dtype::F64) / 3600.0;
    let gpu_h = predict_seconds(&V100, &w, Dtype::F64) / 3600.0;
    println!(
        "  128x E5-2680v4: per-chip {:.2}h aggregated {:.0}h (paper 6.9 / 890 — original code)",
        cpu_h / 128.0,
        cpu_h
    );
    println!(
        "  4x V100:        per-chip {:.2}h aggregated {:.1}h (paper 0.34 / 1.9)",
        gpu_h / 4.0,
        gpu_h
    );
    Ok(())
}
