//! Table-2 style distributed run: partition the stripe set over many
//! simulated chips, time each in isolation, and compare the observed
//! per-chip/aggregated split against the device models.
//!
//! ```bash
//! cargo run --release --example distributed_chips [n_samples] [chips]
//! ```

use unifrac::coordinator::{run, BackendSpec, RunOptions};
use unifrac::devicemodel::{predict_seconds, stage_workload, Dtype, V100, XEON_E5_2680V4};
use unifrac::matrix::total_stripes;
use unifrac::synth::SynthSpec;
use unifrac::unifrac::{EngineKind, Metric};

fn main() -> unifrac::Result<()> {
    let mut args = std::env::args().skip(1);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(512);
    let chips: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(8);

    let (tree, table) = SynthSpec::emp_like(n, 99).generate();
    println!(
        "workload: {} samples, {} tree nodes, {} chips",
        table.n_samples(),
        tree.n_nodes(),
        chips
    );

    // sequential mode = isolated per-chip timing (the paper's Table 2 rows)
    let opts = RunOptions {
        metric: Metric::WeightedNormalized,
        backend: BackendSpec::cpu_tiled(),
        chips,
        parallel: false,
        artifacts_dir: None,
        ..Default::default()
    };
    let seq = run::<f64>(&tree, &table, &opts)?;
    println!("\nsequential (isolated chips):");
    let per: &[f64] = &seq.metrics.per_chip_seconds;
    let mean = per.iter().sum::<f64>() / per.len() as f64;
    let max = seq.metrics.max_chip_seconds();
    println!("  per-chip mean {:.3}s  max {:.3}s", mean, max);
    println!("  aggregated    {:.3}s (the paper's chip-hours analogue)", seq.metrics.aggregate_chip_seconds());
    let imbalance = max / mean;
    println!("  load imbalance (max/mean) = {imbalance:.3}");

    // parallel mode: actual wall-clock speedup on this host
    let par = run::<f64>(&tree, &table, &RunOptions { parallel: true, ..opts.clone() })?;
    println!("\nparallel (threaded chips):");
    println!("  wall {:.3}s  vs sequential aggregate {:.3}s  => speedup {:.2}x",
        par.metrics.seconds_total,
        seq.metrics.aggregate_chip_seconds(),
        seq.metrics.aggregate_chip_seconds() / par.metrics.seconds_total
    );
    assert!(par.dm.max_abs_diff(&seq.dm) < 1e-12, "parallel/sequential mismatch");

    // device-model view of the same partitioning at paper scale
    println!("\ndevice-model projection (113,721 samples, per the paper's Table 2):");
    let (big_n, big_t) = (unifrac::devicemodel::BIG_N_SAMPLES, unifrac::devicemodel::BIG_TREE_NODES);
    let w = stage_workload(EngineKind::Tiled, big_n, total_stripes(big_n), big_t, 64, Dtype::F64);
    let cpu_h = predict_seconds(&XEON_E5_2680V4, &w, Dtype::F64) / 3600.0;
    let gpu_h = predict_seconds(&V100, &w, Dtype::F64) / 3600.0;
    println!("  128x E5-2680v4: per-chip {:.2}h aggregated {:.0}h (paper 6.9 / 890 — original code)", cpu_h / 128.0, cpu_h);
    println!("  4x V100:        per-chip {:.2}h aggregated {:.1}h (paper 0.34 / 1.9)", gpu_h / 4.0, gpu_h);
    Ok(())
}
