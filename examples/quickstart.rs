//! Quickstart: compute UniFrac on a small synthetic microbiome workload.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use unifrac::stats::pcoa;
use unifrac::synth::SynthSpec;
use unifrac::unifrac::{compute_unifrac, ComputeOptions, Metric};

fn main() -> unifrac::Result<()> {
    // 1. A synthetic workload: 64 samples, EMP-like sparsity. Real data
    //    loads the same way via `table::read_table_tsv` + `tree::parse_newick`.
    let (tree, table) = SynthSpec::emp_like(64, 42).generate();
    println!(
        "workload: {} samples x {} features (density {:.3}), tree of {} nodes",
        table.n_samples(),
        table.n_features(),
        table.density(),
        tree.n_nodes()
    );

    // 2. Compute three UniFrac variants with the optimized CPU engine.
    for metric in [
        Metric::Unweighted,
        Metric::WeightedNormalized,
        Metric::Generalized(0.5),
    ] {
        let opts = ComputeOptions { metric, threads: 0, ..Default::default() };
        let dm = compute_unifrac::<f64>(&tree, &table, &opts)?;
        println!(
            "{metric}: d(0,1) = {:.4}, d(0,2) = {:.4}, mean = {:.4}",
            dm.get(0, 1),
            dm.get(0, 2),
            dm.condensed().iter().sum::<f64>() / dm.condensed().len() as f64
        );
    }

    // 3. Downstream ordination (what EMP-style studies do with UniFrac).
    let opts = ComputeOptions { metric: Metric::WeightedNormalized, ..Default::default() };
    let dm = compute_unifrac::<f64>(&tree, &table, &opts)?;
    let ord = pcoa(&dm, 3, 1);
    println!(
        "PCoA: {} axes, leading axis explains {:.1}% of inertia",
        ord.eigenvalues.len(),
        ord.proportion_explained.first().copied().unwrap_or(0.0) * 100.0
    );

    // 4. Persist the matrix in the standard square-TSV layout.
    let out = std::env::temp_dir().join("quickstart_unifrac.tsv");
    dm.write_tsv(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}
