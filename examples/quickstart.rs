//! Quickstart: compute UniFrac on a small synthetic microbiome workload
//! through the `UniFracJob` facade.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use unifrac::stats::pcoa;
use unifrac::synth::SynthSpec;
use unifrac::{Metric, UniFracJob};

fn main() -> unifrac::Result<()> {
    // 1. A synthetic workload: 64 samples, EMP-like sparsity. Real data
    //    loads the same way via `table::read_table_tsv` + `tree::parse_newick`.
    let (tree, table) = SynthSpec::emp_like(64, 42).generate();
    println!(
        "workload: {} samples x {} features (density {:.3}), tree of {} nodes",
        table.n_samples(),
        table.n_features(),
        table.density(),
        tree.n_nodes()
    );

    // 2. Compute three UniFrac variants. `UniFracJob` auto-selects the
    //    engine per metric (bit-packed for unweighted, sparse CSR or
    //    tiled for weighted, by measured density).
    for metric in [
        Metric::Unweighted,
        Metric::WeightedNormalized,
        Metric::Generalized(0.5),
    ] {
        let dm = UniFracJob::new(&tree, &table).metric(metric).threads(0).run()?;
        println!(
            "{metric}: d(0,1) = {:.4}, d(0,2) = {:.4}, mean = {:.4}",
            dm.get(0, 1),
            dm.get(0, 2),
            dm.condensed().iter().sum::<f64>() / dm.condensed().len() as f64
        );
    }

    // 3. Downstream ordination (what EMP-style studies do with UniFrac),
    //    with the run accounting the facade surfaces alongside.
    let out = UniFracJob::new(&tree, &table)
        .metric(Metric::WeightedNormalized)
        .run_output()?;
    println!(
        "engine {} over {} stripes, {:.3e} updates/s",
        out.metrics.backend,
        out.metrics.n_stripes,
        out.metrics.updates_per_second()
    );
    let ord = pcoa(&out.dm, 3, 1);
    println!(
        "PCoA: {} axes, leading axis explains {:.1}% of inertia",
        ord.eigenvalues.len(),
        ord.proportion_explained.first().copied().unwrap_or(0.0) * 100.0
    );

    // 4. Persist the matrix in the standard square-TSV layout.
    let out_path = std::env::temp_dir().join("quickstart_unifrac.tsv");
    out.dm.write_tsv(&out_path)?;
    println!("wrote {}", out_path.display());
    Ok(())
}
