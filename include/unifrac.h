/* unifrac.h — C ABI for the Striped UniFrac shared library.
 *
 * Built from the Rust crate with `cargo build --release` (the crate is
 * a `cdylib`; the library lands at rust/target/release/libunifrac.so /
 * .dylib). Link with `-lunifrac` and any language's FFI.
 *
 * Mirrors the reference implementation's entry points: ssu_one_off
 * (full matrix), ssu_partial (one stripe partial of N),
 * ssu_merge_partials (reassemble), plus persistence and accessors.
 *
 * Contract:
 *   - Fallible functions return an int status: 0 on success, otherwise
 *     a stable per-error-class code (see SSU_* below; 99 = a panic was
 *     caught at the boundary — never propagated into the caller).
 *   - Results come back through opaque handles written to the out
 *     pointer only on success. Free them with ssu_matrix_free /
 *     ssu_partial_free.
 *   - ssu_last_error() returns the calling thread's most recent
 *     failure message (valid until the next failing call).
 */

#ifndef UNIFRAC_H
#define UNIFRAC_H

#include <stddef.h>

#ifdef __cplusplus
extern "C" {
#endif

/* ---- status codes (stable; shared with the CLI's exit codes) ---- */
#define SSU_OK 0
#define SSU_ERR_IO 10
#define SSU_ERR_NEWICK 11
#define SSU_ERR_TABLE 12
#define SSU_ERR_CONFIG 13
#define SSU_ERR_MANIFEST 14
#define SSU_ERR_SHAPE 15
#define SSU_ERR_NO_ARTIFACT 16
#define SSU_ERR_XLA 17
#define SSU_ERR_INVALID 18
#define SSU_ERR_CLI 19
#define SSU_ERR_UNSUPPORTED 20
#define SSU_ERR_MERGE 21
#define SSU_ERR_CORRUPT 22
#define SSU_ERR_OVERLOADED 23 /* query service shed this request */
#define SSU_ERR_DEADLINE 24   /* request ran past its deadline */
#define SSU_ERR_PANIC 99

/* ---- opaque handles ---- */
typedef struct SsuMatrix SsuMatrix;   /* condensed distance matrix */
typedef struct SsuPartial SsuPartial; /* one computed stripe subrange */

/* ---- computation ---- */

/* Full UniFrac distance matrix ("one_off").
 *   table_path     feature table (.tsv, or the binary .bin format)
 *   tree_path      Newick tree
 *   unifrac_method "unweighted" | "weighted_normalized" |
 *                  "weighted_unnormalized" | "generalized" | "emd"
 *                  (emd distances equal weighted_unnormalized; the
 *                  per-branch flows come from ssu_emd_flows)
 *   alpha          generalized-UniFrac exponent (ignored otherwise)
 *   fp32           nonzero computes in single precision
 *   threads        worker threads (0 = all cores)
 *   out            receives a fresh handle on success
 */
int ssu_one_off(const char *table_path, const char *tree_path,
                const char *unifrac_method, double alpha, int fp32,
                unsigned threads, SsuMatrix **out);

/* Full matrix streamed straight to out_path — the out-of-core one_off
 * for EMP-scale workloads; the O(N^2) matrix never materializes in RAM.
 *   format          "tsv"  streamed square TSV (byte-identical to
 *                          ssu_one_off + ssu_matrix_write_tsv)
 *                   "bin"  raw condensed binary (UFDM, little-endian
 *                          f64; see docs/emp-scale.md for the layout)
 *                   "mmap" same bytes via a shared memory mapping,
 *                          RESUMABLE: rerunning after a kill continues
 *                          at the first stripe range not yet flushed
 *   max_resident_mb 0 = one pass; otherwise sweep the stripe space in
 *                   passes whose accumulator scratch fits the budget
 */
int ssu_one_off_to_path(const char *table_path, const char *tree_path,
                        const char *unifrac_method, double alpha, int fp32,
                        unsigned threads, const char *format,
                        unsigned max_resident_mb, const char *out_path);

/* EMDUniFrac differential-abundance flows for one sample pair, written
 * to out_path (as_json nonzero writes the JSON document, otherwise the
 * tab-separated flow table — identical bytes to the CLI's emd-flows
 * subcommand). sample_i / sample_j name the pair by sample id or by
 * 0-based index. The recorded distance equals the pair's
 * weighted_unnormalized UniFrac distance. */
int ssu_emd_flows(const char *table_path, const char *tree_path,
                  const char *sample_i, const char *sample_j, int as_json,
                  const char *out_path);

/* One stripe partial: the partial_index-th of n_partials equal splits
 * of the stripe space. Partials of the same problem/options merge
 * bit-identically to ssu_one_off. Run each on its own process or
 * machine, persist with ssu_partial_save, merge anywhere. */
int ssu_partial(const char *table_path, const char *tree_path,
                const char *unifrac_method, double alpha, int fp32,
                unsigned threads, unsigned partial_index,
                unsigned n_partials, SsuPartial **out);

/* Merge partials into the full matrix. Rejects gaps, overlaps and
 * metadata mismatches with SSU_ERR_MERGE. Inputs are not consumed. */
int ssu_merge_partials(const SsuPartial *const *parts, size_t n_parts,
                       SsuMatrix **out);

/* ---- partial persistence / introspection ---- */
/* Persist a partial as a compact self-describing binary (UFPR). */
int ssu_partial_save(const SsuPartial *p, const char *path);
/* Load a partial previously written by ssu_partial_save. */
int ssu_partial_load(const char *path, SsuPartial **out);
/* First global stripe the partial covers (0 on NULL). */
unsigned ssu_partial_stripe_start(const SsuPartial *p);
/* Number of stripes the partial covers (0 on NULL). */
unsigned ssu_partial_stripe_count(const SsuPartial *p);

/* ---- matrix accessors ---- */
/* Sample count (0 on NULL). */
unsigned ssu_matrix_n_samples(const SsuMatrix *m);
/* Distance (NaN on bad handle/indices; diagonal is 0). */
double ssu_matrix_get(const SsuMatrix *m, unsigned i, unsigned j);
/* Sample id; owned by the handle, valid until ssu_matrix_free. */
const char *ssu_matrix_sample_id(const SsuMatrix *m, unsigned i);
/* Condensed upper-triangle length: n * (n - 1) / 2. */
size_t ssu_matrix_condensed_len(const SsuMatrix *m);
/* Copy the condensed vector (pair order (0,1), (0,2), ...) into buf,
 * which must hold exactly ssu_matrix_condensed_len doubles. */
int ssu_matrix_condensed(const SsuMatrix *m, double *buf, size_t buf_len);
/* Standard square TSV — same formatter as the Rust CLI's --output. */
int ssu_matrix_write_tsv(const SsuMatrix *m, const char *path);

/* ---- lifecycle / diagnostics ---- */
/* Free a matrix handle (NULL is a no-op). */
void ssu_matrix_free(SsuMatrix *m);
/* Free a partial handle (NULL is a no-op). */
void ssu_partial_free(SsuPartial *p);
/* Calling thread's most recent failure message. */
const char *ssu_last_error(void);
/* Static name for a status code ("ok", "merge", "panic", ...). */
const char *ssu_error_name(int code);
const char *ssu_version(void);
/* CPU capability diagnostics: the SIMD kernel path the auto dispatcher
 * selects plus the detected CPU features, e.g.
 * "kernel=avx2 detected=avx2,fma,avx512f". Static storage, valid for
 * the process lifetime. Honors UNIFRAC_FORCE_SCALAR (read once). */
const char *ssu_cpu_features(void);
/* 1 when the GPU stripe engine can run here (a real adapter was
 * detected, or UNIFRAC_GPU_VDEV forces the deterministic virtual
 * device), else 0. Requesting the gpu engine on a 0 host fails with
 * SSU_ERR_UNSUPPORTED unless the "vdev" adapter is selected. */
int ssu_gpu_available(void);

#ifdef __cplusplus
} /* extern "C" */
#endif

#endif /* UNIFRAC_H */
